//! PJRT client wrapper: loads HLO-text artifacts, compiles them on the CPU
//! PJRT plugin, and caches the loaded executables. One compiled executable
//! per (model, shape); compilation happens once at startup, never on the
//! request path.

use super::artifacts::{ArtifactMeta, Manifest};
use crate::anyhow;
use crate::util::error::Result;
use std::collections::HashMap;

/// PJRT client + executable cache.
pub struct RuntimeClient {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl RuntimeClient {
    /// Create a CPU PJRT client over the given artifact directory.
    pub fn new(manifest: Manifest) -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self { client, manifest, cache: HashMap::new() })
    }

    /// Convenience: load the default `artifacts/` directory.
    pub fn from_default_dir() -> Result<Self> {
        Self::new(Manifest::load(Manifest::default_dir())?)
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. cpu).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Look up an artifact by model name and shape.
    pub fn find(&self, fn_name: &str, m: usize, n: usize) -> Result<ArtifactMeta> {
        self.manifest
            .find(fn_name, m, n)
            .cloned()
            .ok_or_else(|| {
                anyhow!(
                    "no artifact for {fn_name} at m={m}, n={n}; available: {:?}",
                    self.manifest.shapes_of(fn_name)
                )
            })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&mut self, meta: &ArtifactMeta) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(&meta.name) {
            let path = self.manifest.path_of(meta);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", meta.name))?;
            self.cache.insert(meta.name.clone(), exe);
        }
        Ok(self.cache.get(&meta.name).unwrap())
    }

    /// Execute an artifact with the given literals; returns the output
    /// tuple elements (jax lowers with `return_tuple=True`).
    pub fn execute(
        &mut self,
        meta: &ArtifactMeta,
        inputs: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let n_out = meta.n_outputs;
        let exe = self.executable(meta)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {}: {e:?}", meta.name))?;
        Self::untuple(&result[0][0], n_out, &meta.name)
    }

    /// Execute with device-resident buffers (the §Perf fast path: loop-
    /// invariant inputs like the data matrix are uploaded once via
    /// [`Self::upload`] instead of per call).
    pub fn execute_buffers(
        &mut self,
        meta: &ArtifactMeta,
        inputs: &[&xla::PjRtBuffer],
    ) -> Result<Vec<xla::Literal>> {
        let n_out = meta.n_outputs;
        let exe = self.executable(meta)?;
        let result = exe
            .execute_b(inputs)
            .map_err(|e| anyhow!("executing {} (buffers): {e:?}", meta.name))?;
        Self::untuple(&result[0][0], n_out, &meta.name)
    }

    /// Upload a literal to the device once (loop-invariant inputs).
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_literal(None, lit)
            .map_err(|e| anyhow!("uploading literal: {e:?}"))
    }

    fn untuple(buf: &xla::PjRtBuffer, n_out: usize, name: &str) -> Result<Vec<xla::Literal>> {
        let lit = buf
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        let parts = lit.to_tuple().map_err(|e| anyhow!("untupling: {e:?}"))?;
        if parts.len() != n_out {
            return Err(anyhow!(
                "{name} returned {} outputs, manifest says {n_out}",
                parts.len()
            ));
        }
        Ok(parts)
    }
}

/// f64 slice → f32 literal of shape `[len]`.
pub fn vec_literal(v: &[f64]) -> xla::Literal {
    let f: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
}

/// f64 scalar → f32 literal of shape `[1]` (the aot.py scalar convention).
pub fn scalar1_literal(x: f64) -> xla::Literal {
    xla::Literal::vec1(&[x as f32])
}

/// Row-major f64 matrix data → f32 literal of shape `[m, n]`.
pub fn matrix_literal(row_major: &[f64], m: usize, n: usize) -> Result<xla::Literal> {
    assert_eq!(row_major.len(), m * n);
    let f: Vec<f32> = row_major.iter().map(|&x| x as f32).collect();
    xla::Literal::vec1(&f)
        .reshape(&[m as i64, n as i64])
        .map_err(|e| anyhow!("reshape to [{m},{n}]: {e:?}"))
}

/// f32 literal → f64 vec.
pub fn literal_to_vec(lit: &xla::Literal) -> Result<Vec<f64>> {
    let v: Vec<f32> = lit.to_vec().map_err(|e| anyhow!("literal to_vec: {e:?}"))?;
    Ok(v.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-dependent tests live in rust/tests/integration_runtime.rs (they
    // need built artifacts); here we only cover the literal helpers.

    #[test]
    fn literal_roundtrip() {
        let v = vec![1.0, -2.5, 3.25];
        let lit = vec_literal(&v);
        assert_eq!(literal_to_vec(&lit).unwrap(), v);
    }

    #[test]
    fn matrix_literal_shape() {
        let lit = matrix_literal(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(lit.element_count(), 6);
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 3]);
    }

    #[test]
    fn scalar1_is_len1() {
        let lit = scalar1_literal(0.5);
        assert_eq!(lit.element_count(), 1);
    }
}
