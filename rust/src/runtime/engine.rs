//! `StepEngine`: the full-Jacobi FLEXA step as a swappable backend.
//!
//! * [`NativeEngine`] — the L3 rust kernels (any shape; what the large
//!   paper-scale benchmarks run);
//! * `XlaEngine` (behind the `pjrt` feature) — the AOT-compiled L2/L1
//!   artifact executed through PJRT (fixed shapes from the manifest; what
//!   proves the three-layer composition on the request path — python is
//!   never invoked).
//!
//! Both compute `(ẑ, E, V(x))` from `(x, τ)`; the rust coordinator layers
//! selection, the memory step, and the τ/γ controllers on top
//! ([`flexa_with_engine`]). Integration tests assert the two engines agree
//! to f32 tolerance on identical iterates.

#[cfg(feature = "pjrt")]
use super::client::{literal_to_vec, matrix_literal, scalar1_literal, vec_literal, RuntimeClient};
use crate::coordinator::{FlexaOptions, SolveReport};
use crate::problems::{LassoProblem, Problem};
use crate::util::error::Result;

/// A backend computing the full-Jacobi step quantities.
pub trait StepEngine {
    /// (m, n) of the problem this engine is bound to.
    fn shape(&self) -> (usize, usize);

    /// Compute best responses `ẑ` (length n), error bounds `e` (length n;
    /// scalar blocks), and return the objective `V(x)`.
    fn step(&mut self, x: &[f64], tau: f64, z: &mut [f64], e: &mut [f64]) -> Result<f64>;

    /// Backend label for reports.
    fn backend(&self) -> &'static str;
}

/// Native rust backend over a [`LassoProblem`].
pub struct NativeEngine<'a> {
    problem: &'a LassoProblem,
    aux: Vec<f64>,
}

impl<'a> NativeEngine<'a> {
    /// New native engine bound to a LASSO problem.
    pub fn new(problem: &'a LassoProblem) -> Self {
        Self { aux: vec![0.0; problem.aux_len()], problem }
    }
}

impl StepEngine for NativeEngine<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.problem.aux_len(), self.problem.n())
    }

    fn step(&mut self, x: &[f64], tau: f64, z: &mut [f64], e: &mut [f64]) -> Result<f64> {
        // full-Jacobi semantics: recompute the residual at x (the engine is
        // stateless across calls, mirroring the XLA artifact)
        self.problem.init_aux(x, &mut self.aux);
        for i in 0..self.problem.n() {
            e[i] = self.problem.best_response(i, x, &self.aux, tau, &mut z[i..=i]);
        }
        Ok(self.problem.v_val(x, &self.aux))
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

/// PJRT backend executing the `lasso_step` artifact.
///
/// The loop-invariant inputs (`A`, `b`) are converted to f32 literals
/// **once** at bind time and cloned (a C++-side memcpy) per call. NOTE:
/// the device-resident `PjRtBuffer` + `execute_b` path would avoid even
/// that, but xla_extension 0.5.1's CPU plugin aborts inside `execute_b`
/// (`Check failed: pointer_size > 0`), so literals are the supported path
/// — see EXPERIMENTS.md §Perf.
#[cfg(feature = "pjrt")]
pub struct XlaEngine {
    client: RuntimeClient,
    meta: crate::runtime::artifacts::ArtifactMeta,
    a_lit: xla::Literal,
    b_lit: xla::Literal,
    m: usize,
    n: usize,
}

#[cfg(feature = "pjrt")]
impl XlaEngine {
    /// Bind the `lasso_step` artifact at the problem's exact shape.
    pub fn for_lasso(client: RuntimeClient, problem: &LassoProblem) -> Result<Self> {
        Self::for_lasso_named(client, problem, "lasso_step")
    }

    /// Bind a named LASSO-step artifact (`lasso_step` / `lasso_step_fused`).
    pub fn for_lasso_named(
        mut client: RuntimeClient,
        problem: &LassoProblem,
        fn_name: &str,
    ) -> Result<Self> {
        let (m, n) = (problem.aux_len(), problem.n());
        let meta = client.find(fn_name, m, n)?;
        // eagerly compile so the request path never hits the compiler
        client.executable(&meta)?;
        let a_rm = problem.matrix().to_dense().to_row_major();
        let a_lit = matrix_literal(&a_rm, m, n)?;
        let b_lit = vec_literal(problem.rhs());
        Ok(Self { client, meta, a_lit, b_lit, m, n })
    }

    /// Execute one step with explicit ℓ1 weight `c`.
    pub fn step_with_c(
        &mut self,
        x: &[f64],
        tau: f64,
        c: f64,
        z: &mut [f64],
        e: &mut [f64],
    ) -> Result<f64> {
        let inputs = vec![
            self.a_lit.clone(),
            self.b_lit.clone(),
            vec_literal(x),
            scalar1_literal(tau),
            scalar1_literal(c),
        ];
        let outs = self.client.execute(&self.meta, &inputs)?;
        let zv = literal_to_vec(&outs[0])?;
        let ev = literal_to_vec(&outs[1])?;
        z.copy_from_slice(&zv);
        e.copy_from_slice(&ev);
        let obj: Vec<f32> = outs[2].to_vec().map_err(|e| crate::anyhow!("{e:?}"))?;
        Ok(obj[0] as f64)
    }

    /// (m, n) shape this engine was lowered for.
    pub fn shape_mn(&self) -> (usize, usize) {
        (self.m, self.n)
    }
}

/// An engine bound to a concrete LASSO instance (carries `c`).
#[cfg(feature = "pjrt")]
pub struct BoundXlaEngine {
    inner: XlaEngine,
    c: f64,
}

#[cfg(feature = "pjrt")]
impl BoundXlaEngine {
    /// Bind an XLA engine to a problem (compiles the artifact eagerly).
    pub fn new(client: RuntimeClient, problem: &LassoProblem) -> Result<Self> {
        Ok(Self { inner: XlaEngine::for_lasso(client, problem)?, c: problem.c() })
    }
}

#[cfg(feature = "pjrt")]
impl StepEngine for BoundXlaEngine {
    fn shape(&self) -> (usize, usize) {
        self.inner.shape_mn()
    }

    fn step(&mut self, x: &[f64], tau: f64, z: &mut [f64], e: &mut [f64]) -> Result<f64> {
        self.inner.step_with_c(x, tau, self.c, z, e)
    }

    fn backend(&self) -> &'static str {
        "xla-pjrt"
    }
}

/// FLEXA (Algorithm 1) driven by a [`StepEngine`] — the end-to-end
/// three-layer path: selection/γ/τ on the rust side, compute in the
/// engine. Since the `SolverCore` refactor this is the same
/// [`SolverSpec::flexa`](crate::engine::SolverSpec::flexa) configuration
/// as the native `coordinator::flexa`, run through
/// [`crate::engine::solve_with_step_engine`]: the fused engine pass
/// replaces the pool-parallel Jacobi scan (it always computes every
/// block, so sketching strategies restrict only the *selection* on this
/// path), and the auxiliary state is recomputed from `x` each iteration
/// (the engine owns the compute). γ now follows the same
/// iteration-indexed schedule as the native path (it advances on
/// τ-discarded iterations too, per Theorem 1).
pub fn flexa_with_engine(
    problem: &LassoProblem,
    engine: &mut dyn StepEngine,
    x0: &[f64],
    opts: &FlexaOptions,
) -> Result<SolveReport> {
    let spec = crate::engine::SolverSpec::flexa(
        opts.common.clone(),
        opts.selection.clone(),
        opts.inexact,
    );
    crate::engine::solve_with_step_engine(problem, engine, x0, &spec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CommonOptions, SelectionSpec, TermMetric};
    use crate::datagen::nesterov_lasso;

    #[test]
    fn native_engine_matches_problem_path() {
        let p = LassoProblem::from_instance(nesterov_lasso(20, 30, 0.2, 1.0, 4));
        let mut eng = NativeEngine::new(&p);
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(2);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut z = vec![0.0; p.n()];
        let mut e = vec![0.0; p.n()];
        let v = eng.step(&x, 0.9, &mut z, &mut e).unwrap();
        // compare against the trait path
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        assert!((v - p.v_val(&x, &aux)).abs() < 1e-10);
        let mut zi = [0.0];
        for i in 0..p.n() {
            let ei = p.best_response(i, &x, &aux, 0.9, &mut zi);
            assert!((z[i] - zi[0]).abs() < 1e-12);
            assert!((e[i] - ei).abs() < 1e-12);
        }
    }

    #[test]
    fn flexa_with_native_engine_converges() {
        let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 11));
        let mut eng = NativeEngine::new(&p);
        let opts = FlexaOptions {
            common: CommonOptions {
                max_iters: 3000,
                tol: 1e-6,
                term: TermMetric::RelErr,
                name: "FLEXA-native-engine".into(),
                ..Default::default()
            },
            selection: SelectionSpec::sigma(0.5),
            inexact: None,
        };
        let r = flexa_with_engine(&p, &mut eng, &vec![0.0; p.n()], &opts).unwrap();
        assert!(r.converged(), "stop={:?} re={}", r.stop, r.final_rel_err);
    }
}
