//! Runtime layer: loads the AOT-compiled HLO artifacts (`make artifacts`)
//! through the PJRT C API and exposes them as [`StepEngine`] backends to
//! the coordinator. Python is build-time only — after the artifacts exist,
//! the rust binary is self-contained.

pub mod artifacts;
pub mod client;
pub mod engine;

pub use artifacts::{ArtifactMeta, Manifest};
pub use client::RuntimeClient;
pub use engine::{flexa_with_engine, BoundXlaEngine, NativeEngine, StepEngine, XlaEngine};
