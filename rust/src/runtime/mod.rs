//! Runtime layer: loads the AOT-compiled HLO artifacts (`make artifacts`)
//! through the PJRT C API and exposes them as [`StepEngine`] backends to
//! the coordinator. Python is build-time only — after the artifacts exist,
//! the rust binary is self-contained.
//!
//! The PJRT client (`client`, `XlaEngine` — link targets only exist with
//! the feature) depends on the external `xla` crate, which is not part of
//! the offline crate set; it is gated behind the `pjrt` cargo feature
//! (vendor the crate and enable the feature to build it). The manifest
//! reader and the [`NativeEngine`] backend compile unconditionally.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
pub mod engine;

pub use artifacts::{ArtifactMeta, Manifest};
#[cfg(feature = "pjrt")]
pub use client::RuntimeClient;
#[cfg(feature = "pjrt")]
pub use engine::{BoundXlaEngine, XlaEngine};
pub use engine::{flexa_with_engine, NativeEngine, StepEngine};
