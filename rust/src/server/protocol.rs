//! The serve wire protocol: newline-delimited JSON, one request object
//! per line, one response object per line, over a plain TCP stream.
//!
//! Request shape (full schema in `docs/SERVING.md`):
//!
//! ```json
//! {"op": "solve", "id": 1, "spec": { …SolveSpec… },
//!  "tenant": "alice", "warm_start": false,
//!  "return_x": true, "return_trace": false}
//! ```
//!
//! `op` defaults to `"solve"`; `ping`, `stats` and `shutdown` take no
//! spec. Responses echo `id` verbatim and carry either `"ok": true` plus
//! the op's payload, or `"ok": false` plus `"error"`.

use crate::spec::SolveSpec;
use crate::util::Json;

/// The operation a request line asks for.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Run a [`SolveSpec`] and return its report.
    Solve,
    /// Liveness probe; responds `{"ok": true, "pong": true}`.
    Ping,
    /// Dump the daemon's cache/job counters.
    Stats,
    /// Stop accepting connections, drain in-flight jobs, exit.
    Shutdown,
}

impl Op {
    fn parse(s: &str) -> Result<Op, String> {
        match s {
            "solve" => Ok(Op::Solve),
            "ping" => Ok(Op::Ping),
            "stats" => Ok(Op::Stats),
            "shutdown" => Ok(Op::Shutdown),
            other => Err(format!("unknown op {other:?} (expected solve|ping|stats|shutdown)")),
        }
    }
}

/// One decoded request line.
#[derive(Debug)]
pub struct Request {
    /// Requested operation (default `solve`).
    pub op: Op,
    /// Client correlation id, echoed verbatim in the response.
    pub id: Option<Json>,
    /// The solve request body; required when `op` is [`Op::Solve`].
    pub spec: Option<SolveSpec>,
    /// Warm-start namespace: when set, the final iterate is stored under
    /// `tenant/fingerprint` after the solve.
    pub tenant: Option<String>,
    /// Opt in to seeding `x0` from the tenant's stored iterate. Off by
    /// default — a warm start changes the trajectory, so it is never
    /// implicit.
    pub warm_start: bool,
    /// Include the solution vector `x` in the response (off by default;
    /// `x` dominates response size for big instances).
    pub return_x: bool,
    /// Include the convergence trace in the response (off by default).
    pub return_trace: bool,
}

impl Request {
    /// Decode one request line. The spec body goes through
    /// [`SolveSpec::from_json`], i.e. the same construction-time
    /// validation as every other frontend.
    pub fn parse(line: &str) -> Result<Request, String> {
        let j = Json::parse(line)?;
        let op = match j.get("op").and_then(Json::as_str) {
            Some(s) => Op::parse(s)?,
            None => Op::Solve,
        };
        let spec = match j.get("spec") {
            Some(s) => Some(SolveSpec::from_json(s).map_err(|e| format!("bad spec: {e}"))?),
            None => None,
        };
        if op == Op::Solve && spec.is_none() {
            return Err("solve request needs a \"spec\" object".into());
        }
        let flag = |k: &str| j.get(k).and_then(Json::as_bool).unwrap_or(false);
        Ok(Request {
            op,
            id: j.get("id").cloned(),
            spec,
            tenant: j.get("tenant").and_then(Json::as_str).map(str::to_string),
            warm_start: flag("warm_start"),
            return_x: flag("return_x"),
            return_trace: flag("return_trace"),
        })
    }
}

/// Start a response object echoing the request id.
pub fn response_base(id: &Option<Json>, ok: bool) -> Json {
    Json::obj(vec![
        ("id", id.clone().unwrap_or(Json::Null)),
        ("ok", Json::Bool(ok)),
    ])
}

/// An `"ok": false` response carrying the error message.
pub fn error_response(id: &Option<Json>, msg: &str) -> Json {
    response_base(id, false).with("error", Json::str(msg))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_ops_parse() {
        let r = Request::parse(r#"{"op":"ping","id":7}"#).unwrap();
        assert_eq!(r.op, Op::Ping);
        assert_eq!(r.id, Some(Json::Num(7.0)));
        assert!(!r.warm_start && !r.return_x && !r.return_trace);
        for (op, want) in [("stats", Op::Stats), ("shutdown", Op::Shutdown)] {
            assert_eq!(Request::parse(&format!("{{\"op\":\"{op}\"}}")).unwrap().op, want);
        }
    }

    #[test]
    fn solve_without_spec_is_rejected() {
        let err = Request::parse(r#"{"op":"solve"}"#).unwrap_err();
        assert!(err.contains("spec"), "{err}");
        // op defaults to solve
        let err = Request::parse(r#"{"id":1}"#).unwrap_err();
        assert!(err.contains("spec"), "{err}");
    }

    #[test]
    fn solve_spec_body_is_validated() {
        let err = Request::parse(
            r#"{"spec":{"problem":{"kind":"lasso","m":10,"n":10},"solver":"nope"}}"#,
        )
        .unwrap_err();
        assert!(err.contains("unknown solver"), "{err}");
    }

    #[test]
    fn error_response_echoes_id() {
        let j = error_response(&Some(Json::str("req-3")), "boom");
        assert_eq!(j.get("id").and_then(Json::as_str), Some("req-3"));
        assert_eq!(j.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(j.get("error").and_then(Json::as_str), Some("boom"));
    }
}
