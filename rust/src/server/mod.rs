//! `flexa serve` — a long-running solve daemon with warm state.
//!
//! Std-only (no crates.io deps, like everything else in this crate):
//! newline-delimited JSON over [`std::net::TcpListener`], one handler
//! thread per connection, jobs dispatched onto shared cached
//! [`WorkerPool`](crate::parallel::WorkerPool)s. Across requests the
//! daemon keeps built [`Problem`](crate::problems::Problem)s with their
//! derived block-`L_I`, memoized column-shard views, worker pools, and
//! per-tenant warm-start iterates — see [`cache`] for the exact keys and
//! `docs/SERVING.md` for the protocol.
//!
//! Determinism contract: a served solve runs [`spec::execute_prepared`]
//! on the cached state, which is the same engine path as a direct
//! in-process solve — responses are **bitwise identical** to
//! [`crate::engine::solve`] with the same spec and `x0`, warm cache or
//! cold (pinned by `tests/integration_serve.rs`).
//!
//! Shutdown semantics: a `shutdown` request flips a flag; the accept
//! loop stops taking new connections, every in-flight (fully received)
//! request runs to completion and its response is written, then the
//! daemon joins its handler threads and returns.

pub mod cache;
pub mod protocol;

use std::io::{self, BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::ServerSettings;
use crate::problems::Problem;
use crate::simulator::CostModel;
use crate::spec::{execute_prepared, ExecOptions};
use crate::util::Json;

pub use cache::{CachedProblem, StateCache};
pub use protocol::{Op, Request};

/// Shared state of a running daemon: the warm caches, the cost model
/// pricing every job's simulated clock, and lifecycle counters.
pub struct ServerState {
    /// Warm problem/pool/iterate caches.
    pub cache: StateCache,
    /// Cost model applied to every solve job (injected at bind time so
    /// tests and benches can pin the deterministic default).
    pub model: CostModel,
    /// Set by the `shutdown` op; the accept loop and idle handlers exit
    /// once it is true.
    pub shutdown: AtomicBool,
    /// Completed solve jobs.
    pub jobs_done: AtomicUsize,
    /// Solve jobs rejected by validation/capability guards.
    pub jobs_failed: AtomicUsize,
}

/// A bound (not yet running) serve daemon.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    state: Arc<ServerState>,
}

impl Server {
    /// Bind on the configured host/port with a hardware-calibrated cost
    /// model (what the CLI does). `port = 0` asks the OS for an
    /// ephemeral port — read it back with [`Server::local_addr`].
    pub fn bind(settings: &ServerSettings) -> io::Result<Server> {
        Self::bind_with(settings, CostModel::calibrated())
    }

    /// Bind with an explicit cost model. Tests and the bench driver pass
    /// `CostModel::default()` so served `sim_s` fields are reproducible
    /// and bitwise-comparable against local solves.
    pub fn bind_with(settings: &ServerSettings, model: CostModel) -> io::Result<Server> {
        let listener = TcpListener::bind((settings.host.as_str(), settings.port))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            cache: StateCache::new(),
            model,
            shutdown: AtomicBool::new(false),
            jobs_done: AtomicUsize::new(0),
            jobs_failed: AtomicUsize::new(0),
        });
        Ok(Server { listener, addr, state })
    }

    /// The bound address (resolves `port = 0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Handle on the daemon state (counters, caches) — usable from the
    /// spawning thread while [`Server::run`] owns the accept loop.
    pub fn state(&self) -> Arc<ServerState> {
        self.state.clone()
    }

    /// Accept-and-serve until a `shutdown` request arrives, then drain:
    /// stop accepting, let in-flight requests finish, join every handler
    /// thread, return.
    pub fn run(self) -> io::Result<()> {
        self.listener.set_nonblocking(true)?;
        let mut handles: Vec<thread::JoinHandle<()>> = Vec::new();
        while !self.state.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let state = self.state.clone();
                    handles.push(thread::spawn(move || handle_connection(state, stream)));
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => return Err(e),
            }
            // reap finished handlers so a long-lived daemon stays flat
            handles.retain(|h| !h.is_finished());
        }
        for h in handles {
            let _ = h.join();
        }
        Ok(())
    }
}

/// Per-connection loop: read lines, answer each with one response line.
/// The read timeout keeps idle handlers responsive to shutdown; on a
/// timeout any partially received line stays buffered and the next read
/// resumes it.
fn handle_connection(state: Arc<ServerState>, stream: TcpStream) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // clean EOF
            Ok(_) => {
                let keep_going = process_line(&state, &line, &mut writer);
                if !line.ends_with('\n') {
                    return; // EOF mid-line: answered what arrived, close
                }
                line.clear();
                if !keep_going {
                    return;
                }
            }
            Err(e)
                if e.kind() == io::ErrorKind::WouldBlock
                    || e.kind() == io::ErrorKind::TimedOut =>
            {
                if state.shutdown.load(Ordering::SeqCst) {
                    if line.is_empty() {
                        return;
                    }
                    // half-received request during drain: allow a short
                    // grace for the rest of the line, then give up
                    let deadline = *drain_deadline
                        .get_or_insert_with(|| Instant::now() + Duration::from_secs(5));
                    if Instant::now() >= deadline {
                        return;
                    }
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(_) => return,
        }
    }
}

/// Decode one request line, run it, write one response line. Returns
/// `false` when the connection should close (write failure).
fn process_line(state: &ServerState, line: &str, writer: &mut TcpStream) -> bool {
    let trimmed = line.trim();
    if trimmed.is_empty() {
        return true;
    }
    let resp = match Request::parse(trimmed) {
        Ok(req) => match req.op {
            Op::Ping => protocol::response_base(&req.id, true).with("pong", Json::Bool(true)),
            Op::Stats => protocol::response_base(&req.id, true)
                .with("cache", state.cache.stats())
                .with("pools", state.cache.pool_stats())
                .with("jobs_done", Json::Num(state.jobs_done.load(Ordering::Relaxed) as f64))
                .with(
                    "jobs_failed",
                    Json::Num(state.jobs_failed.load(Ordering::Relaxed) as f64),
                ),
            Op::Shutdown => {
                state.shutdown.store(true, Ordering::SeqCst);
                protocol::response_base(&req.id, true).with("stopping", Json::Bool(true))
            }
            Op::Solve => solve_job(state, &req),
        },
        Err(e) => protocol::error_response(&None, &e),
    };
    let mut text = resp.to_string_compact();
    text.push('\n');
    writer.write_all(text.as_bytes()).is_ok() && writer.flush().is_ok()
}

/// One solve job on the warm caches. Capability guards and validation
/// errors come back as `"ok": false` responses; the daemon never dies on
/// a bad request.
fn solve_job(state: &ServerState, req: &Request) -> Json {
    let spec = match &req.spec {
        Some(s) => s,
        None => return protocol::error_response(&req.id, "solve request needs a spec"),
    };
    let fingerprint = spec.fingerprint();
    let (problem, problem_hit) = match state.cache.problem(spec) {
        Ok(v) => v,
        Err(e) => {
            state.jobs_failed.fetch_add(1, Ordering::Relaxed);
            return protocol::error_response(&req.id, &e);
        }
    };
    let (pool, pool_hit) = state.cache.pool(spec.threads);
    let (warm, warm_label) = if req.warm_start {
        match req.tenant.as_deref().and_then(|t| state.cache.warm_get(t, &fingerprint)) {
            Some(x) => (Some(x), "hit"),
            None => (None, "miss"),
        }
    } else {
        (None, "off")
    };
    // a WorkerPool serves one solve at a time; jobs wanting the same
    // width queue here instead of spawning duplicate pools
    let guard = pool.lock().unwrap_or_else(|e| e.into_inner());
    let result = execute_prepared(
        spec,
        problem.as_ref() as &dyn Problem,
        ExecOptions { pool: Some(&guard), x0: warm.as_deref(), model: state.model },
    );
    drop(guard);
    match result {
        Ok(report) => {
            if let Some(tenant) = &req.tenant {
                state.cache.warm_put(tenant, &fingerprint, report.x.clone());
            }
            state.jobs_done.fetch_add(1, Ordering::Relaxed);
            protocol::response_base(&req.id, true)
                .with("report", report.to_json_with(req.return_x, req.return_trace))
                .with(
                    "cache",
                    Json::obj(vec![
                        ("problem", Json::str(if problem_hit { "hit" } else { "miss" })),
                        ("pool", Json::str(if pool_hit { "hit" } else { "miss" })),
                        ("warm_start", Json::str(warm_label)),
                    ]),
                )
        }
        Err(e) => {
            state.jobs_failed.fetch_add(1, Ordering::Relaxed);
            protocol::error_response(&req.id, &e)
        }
    }
}
