//! Warm state the serve daemon keeps across requests: built problems
//! with their derived per-block curvature, worker pools, column-shard
//! views, and per-tenant warm-start iterates.
//!
//! Cache keys (documented in `docs/SERVING.md`):
//!
//! * **problems** — the spec's [`SolveSpec::fingerprint`] (compact
//!   problem JSON, sorted keys), so requests differing only in
//!   solver/selection/budgets share one built instance;
//! * **pools** — the worker-thread count;
//! * **warm iterates** — `"{tenant}/{fingerprint}"`, written after every
//!   solve that names a tenant, read only when the request opts in with
//!   `warm_start` (a warm start changes the trajectory, so it must never
//!   be implicit);
//! * **shards** — the owned block range, memoized inside
//!   [`CachedProblem`] per problem.

use std::collections::HashMap;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::linalg::BlockPartition;
use crate::parallel::WorkerPool;
use crate::problems::{Problem, ProblemShard};
use crate::spec::{build_problem, SolveSpec};
use crate::util::Json;

/// Lock a mutex, recovering the data from a poisoned lock (a panicked
/// solve job must not wedge the whole daemon — the cached state is
/// value-semantic and stays usable).
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// A [`ProblemShard`] handle cloned out of the memoized cache. The
/// engine wants `Box<dyn ProblemShard>` per worker; the cache holds one
/// `Arc` per block range and hands out cheap delegating boxes.
struct ArcShard(Arc<dyn ProblemShard>);

impl ProblemShard for ArcShard {
    fn block_range(&self) -> Range<usize> {
        self.0.block_range()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        self.0.best_response(i, x, aux, tau, out)
    }

    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.0.best_response_with(i, x, aux, scratch, tau, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        self.0.apply_block_delta(i, delta, aux)
    }
}

/// A built [`Problem`] plus the derived per-problem state that is pure
/// function of the instance: the per-block curvature bounds `L_I`
/// (computed eagerly, once), the scalar constants (`τ` seeds, Lipschitz,
/// `V*`), and a memo of column-shard views. Implements [`Problem`] by
/// delegation so cached solves run the identical engine path — same
/// inner loops, bitwise-identical iterates — while repeat requests skip
/// the derivations.
pub struct CachedProblem {
    inner: Box<dyn Problem>,
    lips: Vec<f64>,
    lipschitz: f64,
    tau_init: f64,
    tau_min: f64,
    v_star: Option<f64>,
    supports_shard: bool,
    shards: Mutex<HashMap<(usize, usize), Arc<dyn ProblemShard>>>,
}

impl CachedProblem {
    /// Wrap a built problem, eagerly deriving the block-`L_I` vector and
    /// the scalar constants.
    pub fn new(inner: Box<dyn Problem>) -> Self {
        let nb = inner.blocks().n_blocks();
        let lips = (0..nb).map(|i| inner.block_lipschitz(i)).collect();
        let lipschitz = inner.lipschitz();
        let tau_init = inner.tau_init();
        let tau_min = inner.tau_min();
        let v_star = inner.v_star();
        let supports_shard = inner.supports_column_shard();
        Self {
            inner,
            lips,
            lipschitz,
            tau_init,
            tau_min,
            v_star,
            supports_shard,
            shards: Mutex::new(HashMap::new()),
        }
    }
}

impl Problem for CachedProblem {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn aux_len(&self) -> usize {
        self.inner.aux_len()
    }

    fn blocks(&self) -> &BlockPartition {
        self.inner.blocks()
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.inner.init_aux(x, aux)
    }

    fn f_val(&self, x: &[f64], aux: &[f64]) -> f64 {
        self.inner.f_val(x, aux)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.inner.g_val(x)
    }

    fn v_val(&self, x: &[f64], aux: &[f64]) -> f64 {
        self.inner.v_val(x, aux)
    }

    fn block_grad(&self, i: usize, x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.inner.block_grad(i, x, aux, out)
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        self.inner.best_response(i, x, aux, tau, out)
    }

    fn prelude_len(&self) -> usize {
        self.inner.prelude_len()
    }

    fn prelude(&self, x: &[f64], aux: &[f64], scratch: &mut [f64]) {
        self.inner.prelude(x, aux, scratch)
    }

    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.inner.best_response_with(i, x, aux, scratch, tau, out)
    }

    fn flops_prelude(&self) -> f64 {
        self.inner.flops_prelude()
    }

    fn flops_best_response_fresh(&self, i: usize) -> f64 {
        self.inner.flops_best_response_fresh(i)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        self.inner.apply_block_delta(i, delta, aux)
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: Range<usize>,
    ) {
        self.inner.apply_block_delta_rows(i, delta, aux_rows, rows)
    }

    fn prelude_bands(&self) -> Option<(usize, usize)> {
        self.inner.prelude_bands()
    }

    fn prelude_rows(
        &self,
        x: &[f64],
        aux: &[f64],
        rows: Range<usize>,
        band_a: &mut [f64],
        band_b: &mut [f64],
    ) {
        self.inner.prelude_rows(x, aux, rows, band_a, band_b)
    }

    fn f_val_rows(&self, x: &[f64], aux_rows: &[f64], rows: Range<usize>) -> f64 {
        self.inner.f_val_rows(x, aux_rows, rows)
    }

    fn supports_chunked_obj(&self) -> bool {
        self.inner.supports_chunked_obj()
    }

    fn grad_full(&self, x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.inner.grad_full(x, aux, out)
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        self.inner.prox_full(v, step, out)
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        self.inner.merit(x, aux)
    }

    fn tau_init(&self) -> f64 {
        self.tau_init
    }

    fn tau_min(&self) -> f64 {
        self.tau_min
    }

    fn v_star(&self) -> Option<f64> {
        self.v_star
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        self.lips.get(i).copied().unwrap_or_else(|| self.inner.block_lipschitz(i))
    }

    fn column_shard(&self, blocks: Range<usize>) -> Option<Box<dyn ProblemShard>> {
        let key = (blocks.start, blocks.end);
        let mut shards = lock_unpoisoned(&self.shards);
        if let Some(arc) = shards.get(&key) {
            return Some(Box::new(ArcShard(arc.clone())));
        }
        let built: Arc<dyn ProblemShard> = Arc::from(self.inner.column_shard(blocks)?);
        shards.insert(key, built.clone());
        Some(Box::new(ArcShard(built)))
    }

    fn supports_column_shard(&self) -> bool {
        self.supports_shard
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        self.inner.flops_best_response(i)
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        self.inner.flops_aux_update(i)
    }

    fn flops_grad_full(&self) -> f64 {
        self.inner.flops_grad_full()
    }

    fn flops_obj(&self) -> f64 {
        self.inner.flops_obj()
    }
}

/// All warm state of one serve daemon, with hit/miss counters per cache
/// (exposed over the `stats` op and in every solve response, so the
/// integration tests can assert reuse instead of guessing).
pub struct StateCache {
    problems: Mutex<HashMap<String, Arc<CachedProblem>>>,
    pools: Mutex<HashMap<usize, Arc<Mutex<WorkerPool>>>>,
    warm: Mutex<HashMap<String, Vec<f64>>>,
    problem_hits: AtomicUsize,
    problem_misses: AtomicUsize,
    pool_hits: AtomicUsize,
    pool_misses: AtomicUsize,
    warm_hits: AtomicUsize,
    warm_misses: AtomicUsize,
}

impl StateCache {
    /// Fresh, empty cache.
    pub fn new() -> Self {
        Self {
            problems: Mutex::new(HashMap::new()),
            pools: Mutex::new(HashMap::new()),
            warm: Mutex::new(HashMap::new()),
            problem_hits: AtomicUsize::new(0),
            problem_misses: AtomicUsize::new(0),
            pool_hits: AtomicUsize::new(0),
            pool_misses: AtomicUsize::new(0),
            warm_hits: AtomicUsize::new(0),
            warm_misses: AtomicUsize::new(0),
        }
    }

    /// The cached problem for this spec's fingerprint, building (and
    /// deriving block-`L_I` etc.) on first use. Returns `(problem,
    /// hit)`, or the build error (file-backed problems can fail to
    /// load; failures are not cached, so a later request after the file
    /// is fixed retries the build). The build runs under the map lock
    /// on purpose: concurrent first requests for the same instance wait
    /// and share one build instead of racing duplicate ones.
    pub fn problem(&self, spec: &SolveSpec) -> Result<(Arc<CachedProblem>, bool), String> {
        let key = spec.fingerprint();
        let mut map = lock_unpoisoned(&self.problems);
        if let Some(p) = map.get(&key) {
            self.problem_hits.fetch_add(1, Ordering::Relaxed);
            return Ok((p.clone(), true));
        }
        self.problem_misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(CachedProblem::new(build_problem(&spec.problem)?));
        map.insert(key, built.clone());
        Ok((built, false))
    }

    /// The shared pool for a thread count, spawning workers on first
    /// use. Returns `(pool, hit)`. A [`WorkerPool`] serves one solve at
    /// a time (single result slot), hence the `Mutex`: concurrent jobs
    /// with equal `threads` serialize on it rather than over-subscribing
    /// the machine with duplicate pools.
    pub fn pool(&self, threads: usize) -> (Arc<Mutex<WorkerPool>>, bool) {
        let mut map = lock_unpoisoned(&self.pools);
        if let Some(p) = map.get(&threads) {
            self.pool_hits.fetch_add(1, Ordering::Relaxed);
            return (p.clone(), true);
        }
        self.pool_misses.fetch_add(1, Ordering::Relaxed);
        let built = Arc::new(Mutex::new(WorkerPool::new(threads)));
        map.insert(threads, built.clone());
        (built, false)
    }

    /// The stored warm-start iterate for `(tenant, fingerprint)`, if
    /// any; counts a warm hit or miss.
    pub fn warm_get(&self, tenant: &str, fingerprint: &str) -> Option<Vec<f64>> {
        let map = lock_unpoisoned(&self.warm);
        match map.get(&format!("{tenant}/{fingerprint}")) {
            Some(x) => {
                self.warm_hits.fetch_add(1, Ordering::Relaxed);
                Some(x.clone())
            }
            None => {
                self.warm_misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Store a tenant's final iterate for future `warm_start` requests
    /// on the same problem fingerprint.
    pub fn warm_put(&self, tenant: &str, fingerprint: &str, x: Vec<f64>) {
        let mut map = lock_unpoisoned(&self.warm);
        map.insert(format!("{tenant}/{fingerprint}"), x);
    }

    /// Per-pool scheduler accounting for the `stats` op: one entry per
    /// cached pool keyed by its thread count, with the pool's cumulative
    /// [`PoolStats`](crate::parallel::PoolStats) counters (jobs run,
    /// worker-seconds idle at the handoff barrier). A pool that is
    /// mid-solve is reported as `busy` instead of blocking the stats
    /// response until its job finishes.
    pub fn pool_stats(&self) -> Json {
        let map = lock_unpoisoned(&self.pools);
        let mut entries: Vec<(usize, Json)> = map
            .iter()
            .map(|(&threads, pool)| {
                let j = match pool.try_lock() {
                    Ok(p) => {
                        let st = p.stats();
                        Json::obj(vec![
                            ("threads", Json::Num(threads as f64)),
                            ("runs", Json::Num(st.runs as f64)),
                            ("barrier_idle_s", Json::Num(st.barrier_idle_s)),
                        ])
                    }
                    Err(_) => Json::obj(vec![
                        ("threads", Json::Num(threads as f64)),
                        ("busy", Json::Bool(true)),
                    ]),
                };
                (threads, j)
            })
            .collect();
        entries.sort_by_key(|(t, _)| *t);
        Json::Arr(entries.into_iter().map(|(_, j)| j).collect())
    }

    /// Counters + entry counts as the `stats` response payload.
    pub fn stats(&self) -> Json {
        Json::obj(vec![
            ("problems", Json::Num(lock_unpoisoned(&self.problems).len() as f64)),
            ("pools", Json::Num(lock_unpoisoned(&self.pools).len() as f64)),
            ("warm_entries", Json::Num(lock_unpoisoned(&self.warm).len() as f64)),
            ("problem_hits", Json::Num(self.problem_hits.load(Ordering::Relaxed) as f64)),
            ("problem_misses", Json::Num(self.problem_misses.load(Ordering::Relaxed) as f64)),
            ("pool_hits", Json::Num(self.pool_hits.load(Ordering::Relaxed) as f64)),
            ("pool_misses", Json::Num(self.pool_misses.load(Ordering::Relaxed) as f64)),
            ("warm_hits", Json::Num(self.warm_hits.load(Ordering::Relaxed) as f64)),
            ("warm_misses", Json::Num(self.warm_misses.load(Ordering::Relaxed) as f64)),
        ])
    }
}

impl Default for StateCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ProblemSpec;
    use crate::coordinator::Backend;
    use crate::spec::{execute_prepared, ExecOptions};

    fn lasso_spec(seed: u64) -> SolveSpec {
        SolveSpec::builder()
            .problem(ProblemSpec::Lasso { m: 25, n: 35, sparsity: 0.1, c: 1.0, seed })
            .solver("flexa")
            .max_iters(20)
            .tol(0.0)
            .build()
            .unwrap()
    }

    #[test]
    fn problem_cache_hits_on_equal_fingerprint_only() {
        let cache = StateCache::new();
        let (a, hit_a) = cache.problem(&lasso_spec(5)).unwrap();
        assert!(!hit_a);
        let (b, hit_b) = cache.problem(&lasso_spec(5)).unwrap();
        assert!(hit_b);
        assert!(Arc::ptr_eq(&a, &b));
        let (_, hit_c) = cache.problem(&lasso_spec(6)).unwrap();
        assert!(!hit_c);
    }

    #[test]
    fn pool_cache_keys_on_thread_count() {
        let cache = StateCache::new();
        let (p1, h1) = cache.pool(2);
        let (p2, h2) = cache.pool(2);
        let (_, h3) = cache.pool(3);
        assert!(!h1 && h2 && !h3);
        assert!(Arc::ptr_eq(&p1, &p2));
    }

    #[test]
    fn pool_stats_reports_runs_and_busy_per_cached_pool() {
        let cache = StateCache::new();
        let (p, _) = cache.pool(2);
        p.lock().unwrap().run(&|_w| {});
        let stats = cache.pool_stats();
        let arr = stats.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("threads").and_then(Json::as_usize), Some(2));
        assert_eq!(arr[0].get("runs").and_then(Json::as_usize), Some(1));
        assert!(arr[0].get("barrier_idle_s").and_then(Json::as_f64).is_some());
        // a pool held by an in-flight job reports busy instead of blocking
        let guard = p.lock().unwrap();
        let stats = cache.pool_stats();
        assert_eq!(
            stats.as_arr().unwrap()[0].get("busy").and_then(Json::as_bool),
            Some(true)
        );
        drop(guard);
    }

    #[test]
    fn warm_iterates_are_per_tenant_per_fingerprint() {
        let cache = StateCache::new();
        assert!(cache.warm_get("alice", "fp").is_none());
        cache.warm_put("alice", "fp", vec![1.0, 2.0]);
        assert_eq!(cache.warm_get("alice", "fp"), Some(vec![1.0, 2.0]));
        assert!(cache.warm_get("bob", "fp").is_none());
        assert!(cache.warm_get("alice", "fp2").is_none());
        let stats = cache.stats();
        assert_eq!(stats.get("warm_hits").and_then(Json::as_usize), Some(1));
        assert_eq!(stats.get("warm_misses").and_then(Json::as_usize), Some(3));
    }

    #[test]
    fn cached_problem_solves_bitwise_like_a_fresh_build() {
        for backend in [Backend::Shared, Backend::Sharded] {
            let mut spec = lasso_spec(9);
            spec.backend = backend;
            spec.cores = 2;
            let fresh = build_problem(&spec.problem).unwrap();
            let direct =
                execute_prepared(&spec, fresh.as_ref(), ExecOptions::default()).unwrap();
            let cache = StateCache::new();
            // solve twice through the cache: the second run exercises the
            // memoized shards and must still match the fresh build exactly
            let (cached, _) = cache.problem(&spec).unwrap();
            let first =
                execute_prepared(&spec, cached.as_ref() as &dyn Problem, ExecOptions::default())
                    .unwrap();
            let (cached2, hit) = cache.problem(&spec).unwrap();
            assert!(hit);
            let second =
                execute_prepared(&spec, cached2.as_ref() as &dyn Problem, ExecOptions::default())
                    .unwrap();
            assert_eq!(direct.x, first.x, "{backend:?} cold cache diverged");
            assert_eq!(direct.x, second.x, "{backend:?} warm cache diverged");
            assert_eq!(direct.final_obj, second.final_obj);
        }
    }
}
