//! Nonconvex box-constrained quadratic — problem (13) of the paper (§VI-C;
//! Fig. 4 & 5):
//!
//! ```text
//! min  ‖Ax − b‖² − c̄‖x‖²  +  c‖x‖₁     s.t.  −β ≤ x_i ≤ β
//! ```
//!
//! `F` is (markedly) nonconvex: its Hessian is `2AᵀA − 2c̄ I`. Scalar
//! blocks; the auxiliary state is the residual `r = Ax − b` as in LASSO.
//!
//! * `∇_i F = 2A_iᵀ r − 2c̄ x_i`;
//! * per the paper, τ is kept above `tau_min()` so the scalar subproblems
//!   `q(u) = ∇_iF·(u−x_i) + ½(d_i + τ)(u−x_i)² + c|u|` with
//!   `d_i = 2‖A_i‖² − 2c̄` (the exact second-order term) are strongly
//!   convex and solved in closed form: soft-threshold then box clamp
//!   (for a 1-D convex objective the box solution is the projection of the
//!   unconstrained minimizer).

use super::{Problem, ProblemShard};
use crate::datagen::NonconvexQpInstance;
use crate::linalg::{vector, BlockPartition, Matrix};

/// Nonconvex quadratic with box constraints and maintained residual.
pub struct NonconvexQpProblem {
    a: Matrix,
    b: Vec<f64>,
    c: f64,
    cbar: f64,
    box_bound: f64,
    col_sq: Vec<f64>,
    blocks: BlockPartition,
    lipschitz: f64,
    /// reference value for re(x) plots (all solvers converge to the same
    /// stationary point in the paper's tests; estimated offline)
    v_star: Option<f64>,
}

impl NonconvexQpProblem {
    /// Build from raw data; `cbar` is the concavity shift of (13).
    pub fn new(a: Matrix, b: Vec<f64>, c: f64, cbar: f64, box_bound: f64) -> Self {
        assert_eq!(a.nrows(), b.len());
        assert!(c > 0.0 && cbar > 0.0 && box_bound > 0.0);
        let n = a.ncols();
        let col_sq = a.col_sq_norms();
        let lipschitz = a.lipschitz_2ata(30, 0xBEEF) + 2.0 * cbar;
        Self {
            a,
            b,
            c,
            cbar,
            box_bound,
            col_sq,
            blocks: BlockPartition::scalar(n),
            lipschitz,
            v_star: None,
        }
    }

    /// Build from a generated instance (13).
    pub fn from_instance(inst: NonconvexQpInstance) -> Self {
        Self::new(inst.a, inst.b, inst.c, inst.cbar, inst.box_bound)
    }

    /// Attach a reference stationary value for re(x) plots.
    pub fn set_v_star(&mut self, v: f64) {
        self.v_star = Some(v);
    }

    /// ℓ1 weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Concavity shift `c̄`.
    pub fn cbar(&self) -> f64 {
        self.cbar
    }

    /// Box half-width `b` of `X = [−b, b]^n`.
    pub fn box_bound(&self) -> f64 {
        self.box_bound
    }
}

impl Problem for NonconvexQpProblem {
    fn n(&self) -> usize {
        self.a.ncols()
    }

    fn aux_len(&self) -> usize {
        self.a.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.a.matvec(x, aux);
        for (r, bi) in aux.iter_mut().zip(&self.b) {
            *r -= bi;
        }
    }

    fn f_val(&self, x: &[f64], aux: &[f64]) -> f64 {
        vector::nrm2_sq(aux) - self.cbar * vector::nrm2_sq(x)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.c * vector::nrm1(x)
    }

    fn block_grad(&self, i: usize, x: &[f64], aux: &[f64], out: &mut [f64]) {
        out[0] = 2.0 * self.a.col_dot(i, aux) - 2.0 * self.cbar * x[i];
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        debug_assert!(
            tau >= self.tau_min(),
            "tau = {tau} below tau_min = {} — subproblem may be nonconvex",
            self.tau_min()
        );
        let g = 2.0 * self.a.col_dot(i, aux) - 2.0 * self.cbar * x[i];
        let d = 2.0 * self.col_sq[i] - 2.0 * self.cbar; // exact curvature
        let denom = d + tau;
        debug_assert!(denom > 0.0);
        let unclamped = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        let z = unclamped.clamp(-self.box_bound, self.box_bound);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.a.col_axpy(i, delta[0], aux);
        }
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        if delta[0] != 0.0 {
            self.a.col_axpy_range(i, delta[0], aux_rows, rows);
        }
    }

    fn grad_full(&self, x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.a.matvec_t(aux, out);
        for (o, xi) in out.iter_mut().zip(x) {
            *o = 2.0 * *o - 2.0 * self.cbar * xi;
        }
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        // prox of step·c‖·‖₁ + δ_[−β,β]: soft-threshold then clamp
        for (o, &vi) in out.iter_mut().zip(v) {
            *o = vector::soft_threshold(vi, step * self.c)
                .clamp(-self.box_bound, self.box_bound);
        }
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        // paper §VI-C: ‖Z̄(x)‖∞ — the ℓ1 merit with components zeroed when
        // they push outward at an active box bound
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        super::l1_merit_inf(&g, x, self.c, Some(self.box_bound))
    }

    fn tau_init(&self) -> f64 {
        // LASSO rule, kept above tau_min (paper: "τ_i > c̄" extra condition)
        (self.a.gram_trace() / (2.0 * self.n() as f64)).max(self.tau_min())
    }

    fn tau_min(&self) -> f64 {
        // ensures d_i + τ = 2‖A_i‖² − 2c̄ + τ > 0 for every block
        2.0 * self.cbar + 1e-9
    }

    fn v_star(&self) -> Option<f64> {
        self.v_star
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // |∂²_i F| ≤ 2‖A_i‖² + 2c̄ (the concave −c̄‖x‖² term contributes
        // curvature magnitude 2c̄ to every scalar block)
        2.0 * self.col_sq[i] + 2.0 * self.cbar
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // scalar blocks: block index == column index
        Some(Box::new(QpShard {
            a: self.a.columns_range(blocks.clone()),
            c: self.c,
            cbar: self.cbar,
            box_bound: self.box_bound,
            tau_min: self.tau_min(),
            col_sq: self.col_sq[blocks.clone()].to_vec(),
            blocks,
        }))
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        2.0 * self.a.col_nnz(i) as f64 + 10.0
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.a.col_nnz(i) as f64
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.a.nnz() as f64 + 2.0 * self.n() as f64
    }

    fn flops_obj(&self) -> f64 {
        2.0 * (self.aux_len() + 2 * self.n()) as f64
    }
}

/// Column shard of a [`NonconvexQpProblem`]: the owned scalar blocks'
/// columns plus the curvature constants of (13). Inner loops mirror the
/// full problem exactly, so results are bitwise equal.
struct QpShard {
    /// The shard's columns `A_s` (m × |blocks|).
    a: Matrix,
    /// ℓ1 weight `c`.
    c: f64,
    /// Concavity shift `c̄`.
    cbar: f64,
    /// Box half-width `β`.
    box_bound: f64,
    /// Convexity floor for τ (`2c̄ + ε`), for the well-posedness check.
    tau_min: f64,
    /// Squared column norms of the owned columns.
    col_sq: Vec<f64>,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for QpShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        debug_assert!(
            tau >= self.tau_min,
            "tau = {tau} below tau_min = {} — subproblem may be nonconvex",
            self.tau_min
        );
        let j = i - self.blocks.start;
        let g = 2.0 * self.a.col_dot(j, aux) - 2.0 * self.cbar * x[i];
        let d = 2.0 * self.col_sq[j] - 2.0 * self.cbar; // exact curvature
        let denom = d + tau;
        debug_assert!(denom > 0.0);
        let unclamped = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        let z = unclamped.clamp(-self.box_bound, self.box_bound);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.a.col_axpy(i - self.blocks.start, delta[0], aux);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::nonconvex_qp;

    fn small() -> NonconvexQpProblem {
        NonconvexQpProblem::from_instance(nonconvex_qp(20, 30, 0.1, 10.0, 50.0, 1.0, 13))
    }

    #[test]
    fn column_shard_matches_full_problem_bitwise() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(3);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.uniform(-0.8, 0.8)).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = p.tau_min() + 3.0;
        let shard = p.column_shard(5..25).expect("qp shards");
        let (mut zf, mut zs) = ([0.0], [0.0]);
        for i in 5..25 {
            let ef = p.best_response(i, &x, &aux, tau, &mut zf);
            let es = shard.best_response(i, &x, &aux, tau, &mut zs);
            assert_eq!(ef, es, "E_{i}");
            assert_eq!(zf[0], zs[0], "zhat_{i}");
        }
    }

    #[test]
    fn f_is_nonconvex_here() {
        // min eig of Hessian = λmin(2AᵀA) − 2c̄ < 0 when c̄ dominates:
        // with n > m, AᵀA is singular ⇒ λmin(2AᵀA) = 0 ⇒ min eig = −2c̄.
        let p = small();
        assert!(p.n() > p.aux_len());
        assert!(p.cbar() > 0.0);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(6);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.uniform(-0.5, 0.5)).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut g = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut g);
        let h = 1e-6;
        for i in [0, 11, 29] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut ap = vec![0.0; p.aux_len()];
            p.init_aux(&xp, &mut ap);
            let mut xm = x.clone();
            xm[i] -= h;
            let mut am = vec![0.0; p.aux_len()];
            p.init_aux(&xm, &mut am);
            let fd = (p.f_val(&xp, &ap) - p.f_val(&xm, &am)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn best_response_stays_in_box_and_minimizes() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(7);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.uniform(-1.0, 1.0)).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = p.tau_min() + 5.0;
        let q = |i: usize, u: f64, g: f64, d: f64| -> f64 {
            g * (u - x[i]) + 0.5 * (d + tau) * (u - x[i]).powi(2) + p.c() * u.abs()
        };
        let mut z = [0.0];
        for i in [0, 9, 21] {
            p.best_response(i, &x, &aux, tau, &mut z);
            assert!(z[0].abs() <= p.box_bound() + 1e-12);
            let mut gi = [0.0];
            p.block_grad(i, &x, &aux, &mut gi);
            let d = 2.0 * p.col_sq[i] - 2.0 * p.cbar();
            let qz = q(i, z[0], gi[0], d);
            // feasible perturbations must not improve
            for du in [-0.05, 0.05, -0.3, 0.3] {
                let u = (z[0] + du).clamp(-p.box_bound(), p.box_bound());
                assert!(q(i, u, gi[0], d) >= qz - 1e-9, "i={i} du={du}");
            }
        }
    }

    #[test]
    fn prox_respects_box_and_threshold() {
        let p = small();
        let v = vec![2.0, -2.0, 0.001, 0.0];
        let mut out = vec![0.0; 4];
        p.prox_full(&v[..], 1e-4, &mut out);
        assert!(out[0] <= p.box_bound());
        assert!(out[1] >= -p.box_bound());
        assert_eq!(out[3], 0.0);
    }

    #[test]
    fn tau_min_keeps_subproblems_convex() {
        let p = small();
        let tau = p.tau_min();
        for i in 0..p.n() {
            let d = 2.0 * p.col_sq[i] - 2.0 * p.cbar();
            assert!(d + tau > 0.0, "block {i} still nonconvex at tau_min");
        }
        assert!(p.tau_init() >= p.tau_min());
    }

    #[test]
    fn merit_zero_when_clamped_stationary() {
        // At a point where every coordinate sits at a bound with outward
        // gradient pressure, Z̄ must vanish.
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        // run a few hundred best-response passes to approach stationarity
        p.init_aux(&x, &mut aux);
        let tau = p.tau_min() + 1.0;
        let mut z = [0.0];
        for _ in 0..300 {
            for i in 0..p.n() {
                p.best_response(i, &x, &aux, tau, &mut z);
                let d = z[0] - x[i];
                if d != 0.0 {
                    x[i] = z[0];
                    p.apply_block_delta(i, &[d], &mut aux);
                }
            }
        }
        let m = p.merit(&x, &aux);
        assert!(m < 1e-6, "merit after GS passes: {m}");
    }
}
