//! LASSO: `min ‖Ax − b‖² + c‖x‖₁` (paper §II, §VI-A; Fig. 1 & 2).
//!
//! Scalar blocks. The auxiliary state is the residual `r = Ax − b`:
//!
//! * `F(x) = ‖r‖²` — O(m) from the maintained residual;
//! * `∇_i F = 2 A_iᵀ r` — one column dot;
//! * best response (paper §IV, Example #2 with `P_i(x_i;x^k) = F(x_i,
//!   x_{−i}^k)`, i.e. the *exact* scalar subproblem, sharper than a plain
//!   linearization):
//!   `x̂_i = ST(x_i − ∇_iF/(2d_i + τ), c/(2d_i + τ))` with `d_i = ‖A_i‖²`;
//! * selective updates: `r += δ_i A_i` — one column axpy per moved block.

use super::{Problem, ProblemShard};
use crate::datagen::LassoInstance;
use crate::linalg::{vector, BlockPartition, Matrix, NumericsTier};

/// LASSO problem with maintained residual.
pub struct LassoProblem {
    a: Matrix,
    b: Vec<f64>,
    c: f64,
    /// squared column norms `d_j = ‖A_j‖²`
    col_sq: Vec<f64>,
    blocks: BlockPartition,
    v_star: Option<f64>,
    lipschitz: f64,
}

impl LassoProblem {
    /// Build from raw data; `v_star` enables relative-error plots.
    pub fn new(a: Matrix, b: Vec<f64>, c: f64, v_star: Option<f64>) -> Self {
        assert_eq!(a.nrows(), b.len());
        assert!(c > 0.0);
        let n = a.ncols();
        let col_sq = a.col_sq_norms();
        let lipschitz = a.lipschitz_2ata(30, 0x5EED);
        Self { a, b, c, col_sq, blocks: BlockPartition::scalar(n), v_star, lipschitz }
    }

    /// Build from a generated instance with known optimum.
    pub fn from_instance(inst: LassoInstance) -> Self {
        let v_star = Some(inst.v_star);
        Self::new(inst.a, inst.b, inst.c, v_star)
    }

    /// The data matrix `A`.
    pub fn matrix(&self) -> &Matrix {
        &self.a
    }

    /// The right-hand side `b`.
    pub fn rhs(&self) -> &[f64] {
        &self.b
    }

    /// ℓ1 weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Squared column norms `‖A_j‖²` (best-response curvatures).
    pub fn col_sq_norms(&self) -> &[f64] {
        &self.col_sq
    }
}

impl Problem for LassoProblem {
    fn n(&self) -> usize {
        self.a.ncols()
    }

    fn aux_len(&self) -> usize {
        self.a.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.a.matvec(x, aux);
        for (r, bi) in aux.iter_mut().zip(&self.b) {
            *r -= bi;
        }
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        vector::nrm2_sq(aux)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.c * vector::nrm1(x)
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        out[0] = 2.0 * self.a.col_dot(i, aux);
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let g = 2.0 * self.a.col_dot(i, aux);
        let denom = 2.0 * self.col_sq[i] + tau;
        debug_assert!(denom > 0.0, "degenerate column {i} with tau = {tau}");
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        let g = 2.0 * self.a.col_dot_with(tier, i, aux);
        let denom = 2.0 * self.col_sq[i] + tau;
        debug_assert!(denom > 0.0, "degenerate column {i} with tau = {tau}");
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.a.col_axpy(i, delta[0], aux);
        }
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        if delta[0] != 0.0 {
            self.a.col_axpy_range(i, delta[0], aux_rows, rows);
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        vector::nrm2_sq(aux_rows)
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.a.matvec_t(aux, out);
        vector::scale(2.0, out);
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        vector::soft_threshold_vec(v, step * self.c, out);
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        super::l1_merit_inf(&g, x, self.c, None)
    }

    fn tau_init(&self) -> f64 {
        // paper §VI-A: τ_i = tr(AᵀA)/2n
        self.a.gram_trace() / (2.0 * self.n() as f64)
    }

    fn v_star(&self) -> Option<f64> {
        self.v_star
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // scalar blocks: ∂²_i F = 2‖A_i‖²
        2.0 * self.col_sq[i]
    }

    fn block_rows(&self, i: usize) -> Option<Vec<usize>> {
        // scalar blocks: best_response(i) reads aux only on column i's
        // row support (one col_dot) and apply_block_delta writes the
        // same rows (one col_axpy) — the locality contract holds exactly
        // on the sparse storage; dense columns touch every residual row.
        self.a.col_rows(i).map(|r| r.to_vec())
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // scalar blocks: block index == column index
        Some(Box::new(LassoShard {
            a: self.a.columns_range(blocks.clone()),
            c: self.c,
            col_sq: self.col_sq[blocks.clone()].to_vec(),
            blocks,
        }))
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        // column dot + soft-threshold
        2.0 * self.a.col_nnz(i) as f64 + 6.0
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.a.col_nnz(i) as f64
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.a.nnz() as f64 + self.n() as f64
    }

    fn flops_obj(&self) -> f64 {
        2.0 * (self.aux_len() + self.n()) as f64
    }
}

/// Column shard of a [`LassoProblem`]: the owned scalar blocks' columns
/// plus their squared norms — everything the owner-computes scan and the
/// partial residual update touch. Inner loops are identical to the full
/// problem, so results are bitwise equal.
struct LassoShard {
    /// The shard's columns `A_s` (m × |blocks|).
    a: Matrix,
    /// ℓ1 weight `c`.
    c: f64,
    /// Squared column norms of the owned columns.
    col_sq: Vec<f64>,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for LassoShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let j = i - self.blocks.start;
        let g = 2.0 * self.a.col_dot(j, aux);
        let denom = 2.0 * self.col_sq[j] + tau;
        debug_assert!(denom > 0.0, "degenerate column {i} with tau = {tau}");
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        let j = i - self.blocks.start;
        let g = 2.0 * self.a.col_dot_with(tier, j, aux);
        let denom = 2.0 * self.col_sq[j] + tau;
        debug_assert!(denom > 0.0, "degenerate column {i} with tau = {tau}");
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.a.col_axpy(i - self.blocks.start, delta[0], aux);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::nesterov_lasso;

    fn small() -> LassoProblem {
        LassoProblem::from_instance(nesterov_lasso(20, 30, 0.2, 1.0, 42))
    }

    #[test]
    fn column_shard_matches_full_problem_bitwise() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(21);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.4).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let shard = p.column_shard(7..19).expect("lasso shards");
        assert_eq!(shard.block_range(), 7..19);
        let (mut zf, mut zs) = ([0.0], [0.0]);
        for i in 7..19 {
            let ef = p.best_response(i, &x, &aux, 0.7, &mut zf);
            let es = shard.best_response(i, &x, &aux, 0.7, &mut zs);
            assert_eq!(ef, es, "E_{i}");
            assert_eq!(zf[0], zs[0], "zhat_{i}");
            let mut af = aux.clone();
            let mut as_ = aux.clone();
            p.apply_block_delta(i, &[0.3], &mut af);
            shard.apply_block_delta(i, &[0.3], &mut as_);
            assert_eq!(af, as_, "delta column {i}");
        }
    }

    #[test]
    fn aux_is_residual() {
        let p = small();
        let x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        for (ai, bi) in aux.iter().zip(p.rhs()) {
            assert!((ai + bi).abs() < 1e-12); // r = -b at x = 0
        }
        // objective at zero = ‖b‖²
        assert!((p.f_val(&x, &aux) - vector::nrm2_sq(p.rhs())).abs() < 1e-10);
        assert_eq!(p.g_val(&x), 0.0);
    }

    #[test]
    fn block_grad_matches_full_grad() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(9);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal()).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut gfull = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut gfull);
        for i in 0..p.n() {
            let mut gi = [0.0];
            p.block_grad(i, &x, &aux, &mut gi);
            assert!((gi[0] - gfull[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn grad_matches_finite_differences() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(17);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut g = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut g);
        let h = 1e-6;
        for i in [0, 7, 29] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut auxp = vec![0.0; p.aux_len()];
            p.init_aux(&xp, &mut auxp);
            let mut xm = x.clone();
            xm[i] -= h;
            let mut auxm = vec![0.0; p.aux_len()];
            p.init_aux(&xm, &mut auxm);
            let fd = (p.f_val(&xp, &auxp) - p.f_val(&xm, &auxm)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i} fd={fd} g={}", g[i]);
        }
    }

    #[test]
    fn best_response_solves_scalar_subproblem() {
        // x̂_i minimizes q(u) = F(u, x_{-i}) + τ/2 (u-x_i)² + c|u|; check by
        // sampling around the returned point.
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(3);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.5).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = 0.7;
        let q = |i: usize, u: f64| -> f64 {
            let mut xt = x.clone();
            xt[i] = u;
            let mut at = vec![0.0; p.aux_len()];
            p.init_aux(&xt, &mut at);
            p.f_val(&xt, &at) + tau / 2.0 * (u - x[i]).powi(2) + p.c() * u.abs()
        };
        for i in [0, 5, 13] {
            let mut z = [0.0];
            let e = p.best_response(i, &x, &aux, tau, &mut z);
            assert!((e - (z[0] - x[i]).abs()).abs() < 1e-12);
            let qz = q(i, z[0]);
            for du in [-0.01, 0.01, -0.1, 0.1] {
                assert!(q(i, z[0] + du) >= qz - 1e-9, "i={i} du={du}");
            }
        }
    }

    #[test]
    fn best_response_fixed_point_at_optimum() {
        // At x* from the Nesterov generator, x̂(x*) = x* (Prop. 8b).
        let inst = nesterov_lasso(25, 40, 0.1, 1.0, 5);
        let x_star = inst.x_star.clone();
        let p = LassoProblem::from_instance(inst);
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x_star, &mut aux);
        let mut z = [0.0];
        for i in 0..p.n() {
            let e = p.best_response(i, &x_star, &aux, 1.0, &mut z);
            assert!(e < 1e-9, "block {i}: E_i = {e}");
        }
        // merit is ~0 at the optimum
        assert!(p.merit(&x_star, &aux) < 1e-9);
    }

    #[test]
    fn incremental_aux_matches_recompute() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(8);
        for _ in 0..50 {
            let i = rng.next_usize(p.n());
            let d = rng.next_normal() * 0.2;
            x[i] += d;
            p.apply_block_delta(i, &[d], &mut aux);
        }
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-9);
    }

    #[test]
    fn tau_init_matches_paper_formula() {
        let p = small();
        let expect = p.matrix().gram_trace() / (2.0 * p.n() as f64);
        assert!((p.tau_init() - expect).abs() < 1e-12);
        assert!(p.tau_init() > 0.0);
    }

    #[test]
    fn flop_accounting_positive() {
        let p = small();
        assert!(p.flops_best_response(0) > 0.0);
        assert!(p.flops_aux_update(0) > 0.0);
        assert!(p.flops_grad_full() > p.flops_best_response(0));
        assert!(p.flops_obj() > 0.0);
    }
}
