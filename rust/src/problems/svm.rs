//! ℓ1-regularized ℓ2-loss SVM (paper §II, fifth bullet):
//!
//! ```text
//! min  Σ_j max(0, 1 − a_j y_jᵀ x)²  +  c‖x‖₁
//! ```
//!
//! The squared hinge is C¹ with Lipschitz gradient (A2/A3 hold), so the
//! FLEXA theory applies directly. As for logistic regression we fold the
//! labels into the data (`Ỹ = diag(a)·Y`) and maintain the margins
//! `u = Ỹx`:
//!
//! * `F(x) = Σ_j max(0, 1 − u_j)²`;
//! * `∇F(x) = −2 Ỹᵀ h`, `h_j = max(0, 1 − u_j)` (active hinge residual);
//! * best response: damped Newton through the soft threshold with the
//!   generalized Hessian diagonal `H_ii = 2 Σ_{j: u_j<1} Ỹ_{ji}²`.

use super::{Problem, ProblemShard};
use crate::linalg::{vector, BlockPartition, Matrix};

/// ℓ2-loss SVM with maintained margins.
pub struct SvmProblem {
    /// label-scaled data Ỹ (m×n)
    y: Matrix,
    c: f64,
    blocks: BlockPartition,
    /// squared column norms `‖Ỹ_i‖²` (per-block curvature bounds /2)
    col_sq: Vec<f64>,
    lipschitz: f64,
}

impl SvmProblem {
    /// `y`: m×n rows = samples; `labels` ∈ {−1, +1}.
    pub fn new(y: Matrix, labels: &[f64], c: f64) -> Self {
        assert_eq!(y.nrows(), labels.len());
        assert!(c > 0.0);
        // reuse the logistic label-folding path
        let folded = fold_labels(y, labels);
        let n = folded.ncols();
        // L_∇F ≤ 2 λmax(ỸᵀỸ) ≤ 2 tr(ỸᵀỸ)
        let lipschitz = 2.0 * folded.gram_trace();
        let col_sq = folded.col_sq_norms();
        Self { y: folded, c, blocks: BlockPartition::scalar(n), col_sq, lipschitz }
    }

    /// ℓ1 weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Number of samples m.
    pub fn m(&self) -> usize {
        self.y.nrows()
    }
}

/// Shared scalar best-response kernel: the fused margin-residual partial
/// `g = −2 Σ_{active} Ỹ_{ji}(1 − u_j)` / active-hinge generalized-Hessian
/// `h = 2 Σ_{active} Ỹ_{ji}²` pass over one label-scaled column, followed
/// by the damped-Newton soft-threshold. One body serves the full problem
/// and its column shard (`col` is the caller's local column index), so
/// the two paths can never drift numerically.
fn hinge_best_response(
    y: &Matrix,
    col: usize,
    x_i: f64,
    aux: &[f64],
    c: f64,
    tau: f64,
    out: &mut [f64],
) -> f64 {
    let (mut g, mut h) = (0.0, 0.0);
    match y {
        Matrix::Dense(d) => {
            for (v, &u) in d.col(col).iter().zip(aux) {
                let r = 1.0 - u;
                if r > 0.0 {
                    g -= v * r;
                    h += v * v;
                }
            }
        }
        Matrix::Sparse(s) => {
            let (rows, vals) = s.col(col);
            for (&r0, &v) in rows.iter().zip(vals) {
                let r = 1.0 - aux[r0];
                if r > 0.0 {
                    g -= v * r;
                    h += v * v;
                }
            }
        }
    }
    g *= 2.0;
    h *= 2.0;
    let denom = h + tau;
    debug_assert!(denom > 0.0);
    let z = vector::soft_threshold(x_i - g / denom, c / denom);
    out[0] = z;
    (z - x_i).abs()
}

fn fold_labels(mut y: Matrix, labels: &[f64]) -> Matrix {
    match &mut y {
        Matrix::Dense(d) => {
            for j in 0..d.ncols() {
                let col = d.col_mut(j);
                for (i, v) in col.iter_mut().enumerate() {
                    *v *= labels[i];
                }
            }
            y
        }
        Matrix::Sparse(s) => {
            let (m, n) = (s.nrows(), s.ncols());
            let mut triplets = Vec::with_capacity(s.nnz());
            for j in 0..n {
                let (rows, vals) = s.col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    triplets.push((i, j, v * labels[i]));
                }
            }
            Matrix::Sparse(crate::linalg::CscMatrix::from_triplets(m, n, &triplets))
        }
    }
}

impl Problem for SvmProblem {
    fn n(&self) -> usize {
        self.y.ncols()
    }

    fn aux_len(&self) -> usize {
        self.y.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.y.matvec(x, aux);
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        aux.iter().map(|&u| (1.0 - u).max(0.0).powi(2)).sum()
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.c * vector::nrm1(x)
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        let mut acc = 0.0;
        match &self.y {
            Matrix::Dense(d) => {
                for (v, &u) in d.col(i).iter().zip(aux) {
                    acc += v * (1.0 - u).max(0.0);
                }
            }
            Matrix::Sparse(s) => {
                let (rows, vals) = s.col(i);
                for (&r, &v) in rows.iter().zip(vals) {
                    acc += v * (1.0 - aux[r]).max(0.0);
                }
            }
        }
        out[0] = -2.0 * acc;
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        hinge_best_response(&self.y, i, x[i], aux, self.c, tau, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.y.col_axpy(i, delta[0], aux);
        }
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        if delta[0] != 0.0 {
            self.y.col_axpy_range(i, delta[0], aux_rows, rows);
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        aux_rows.iter().map(|&u| (1.0 - u).max(0.0).powi(2)).sum()
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        let h: Vec<f64> = aux.iter().map(|&u| (1.0 - u).max(0.0)).collect();
        self.y.matvec_t(&h, out);
        vector::scale(-2.0, out);
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        vector::soft_threshold_vec(v, step * self.c, out);
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        super::l1_merit_inf(&g, x, self.c, None)
    }

    fn tau_init(&self) -> f64 {
        self.y.gram_trace() / (2.0 * self.n() as f64)
    }

    fn tau_min(&self) -> f64 {
        // the active-hinge generalized-Hessian diagonal h_i vanishes when
        // every hinge touching column i deactivates, so the exact τ = 0
        // subproblem is ill-posed (0/0). A tiny scale-aware floor keeps
        // the denominator positive; in the h = 0 regime the gradient
        // partial g is 0 too, so the floored step reduces to the correct
        // τ → 0 limit ST(x_i, c/τ) → 0. The engine floors any pinned τ
        // (GRock's τ = 0) at this value.
        1e-9 * self.tau_init()
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // scalar blocks: generalized Hessian diag ≤ 2‖Ỹ_i‖²
        2.0 * self.col_sq[i]
    }

    fn block_rows(&self, i: usize) -> Option<Vec<usize>> {
        // scalar blocks: hinge_best_response reads margins only on
        // column i's row support and apply_block_delta writes the same
        // rows — the dag locality contract holds on sparse storage.
        self.y.col_rows(i).map(|r| r.to_vec())
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // scalar blocks: block index == column index
        Some(Box::new(SvmShard {
            y: self.y.columns_range(blocks.clone()),
            c: self.c,
            blocks,
        }))
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        5.0 * self.y.col_nnz(i) as f64 + 8.0
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.y.col_nnz(i) as f64
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.y.nnz() as f64 + 2.0 * self.aux_len() as f64
    }

    fn flops_obj(&self) -> f64 {
        3.0 * self.aux_len() as f64 + 2.0 * self.n() as f64
    }
}

/// Column shard of an [`SvmProblem`]: the owned scalar blocks'
/// label-scaled columns. Both paths run the single
/// [`hinge_best_response`] kernel (margin-residual partial + active-hinge
/// generalized-Hessian diagonal), so results are bitwise equal by
/// construction, not by parallel maintenance of two loops.
struct SvmShard {
    /// The shard's label-scaled columns `Ỹ_s` (m × |blocks|).
    y: Matrix,
    /// ℓ1 weight `c`.
    c: f64,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for SvmShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        hinge_best_response(&self.y, i - self.blocks.start, x[i], aux, self.c, tau, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.y.col_axpy(i - self.blocks.start, delta[0], aux);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{logistic_like, LogisticPreset};

    fn small() -> SvmProblem {
        let inst = logistic_like(LogisticPreset::Gisette, 0.01, 123);
        SvmProblem::new(inst.y, &inst.labels, 0.25)
    }

    #[test]
    fn column_shard_matches_full_problem_bitwise() {
        // both the dense (gisette-like) and sparse (real-sim-like) storages
        for p in [small(), {
            let inst = logistic_like(LogisticPreset::RealSim, 0.005, 19);
            SvmProblem::new(inst.y, &inst.labels, 0.25)
        }] {
            let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(13);
            let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
            let mut aux = vec![0.0; p.aux_len()];
            p.init_aux(&x, &mut aux);
            let lo = p.n() / 4;
            let hi = 3 * p.n() / 4;
            let shard = p.column_shard(lo..hi).expect("svm shards");
            assert_eq!(shard.block_range(), lo..hi);
            let (mut zf, mut zs) = ([0.0], [0.0]);
            for i in lo..hi {
                let ef = p.best_response(i, &x, &aux, 0.7, &mut zf);
                let es = shard.best_response(i, &x, &aux, 0.7, &mut zs);
                assert_eq!(ef, es, "E_{i}");
                assert_eq!(zf[0], zs[0], "zhat_{i}");
                let mut af = aux.clone();
                let mut as_ = aux.clone();
                p.apply_block_delta(i, &[0.2], &mut af);
                shard.apply_block_delta(i, &[0.2], &mut as_);
                assert_eq!(af, as_, "delta column {i}");
            }
        }
    }

    #[test]
    fn tau_floor_keeps_inactive_hinge_subproblem_well_posed() {
        let p = small();
        assert!(p.tau_min() > 0.0, "svm must refuse a pinned τ = 0");
        // margins u_j = 2 > 1 deactivate every hinge: h = g = 0, and the
        // τ-floored step must stay finite and hit the τ → 0 limit (zero)
        let mut aux = vec![2.0; p.aux_len()];
        let x = vec![0.3; p.n()];
        let mut z = [f64::NAN];
        let e = p.best_response(0, &x, &aux, p.tau_min(), &mut z);
        assert!(z[0].is_finite() && e.is_finite(), "0/0 leaked through the floor");
        assert_eq!(z[0], 0.0, "no-active-hinge exact step must zero the block");
        // one active hinge again: a normal damped-Newton step, still finite
        aux[0] = 0.0;
        let e = p.best_response(0, &x, &aux, p.tau_min(), &mut z);
        assert!(z[0].is_finite() && e.is_finite());
    }

    #[test]
    fn grock_stays_finite_on_svm_via_the_engine_tau_floor() {
        // GRock pins τ0 = 0; the engine floors it at tau_min() so the
        // inactive-hinge 0/0 hazard cannot poison the iterates with NaN
        use crate::coordinator::{CommonOptions, TermMetric};
        use crate::engine::{self, SolverSpec};
        let p = small();
        let c = CommonOptions {
            max_iters: 150,
            tol: 0.0,
            term: TermMetric::Merit,
            name: "grock-svm".into(),
            ..Default::default()
        };
        let r = engine::solve(&p, &vec![0.0; p.n()], &SolverSpec::grock(c, 4));
        // the fixed hazard is 0/0 = NaN specifically; GRock may still
        // legitimately stall/overflow on adversarial data (the engine
        // reports StopReason::Stalled for that), so assert NaN-freedom
        assert!(!r.final_obj.is_nan(), "objective went NaN");
        assert!(r.x.iter().all(|v| !v.is_nan()), "NaN leaked into the iterate");
    }

    #[test]
    fn objective_at_zero_is_m() {
        // u = 0 ⇒ every hinge = 1 ⇒ F = m
        let p = small();
        let x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        assert!((p.f_val(&x, &aux) - p.m() as f64).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(3);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.2).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut g = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut g);
        let h = 1e-6;
        for i in [0, 5, p.n() - 1] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut ap = vec![0.0; p.aux_len()];
            p.init_aux(&xp, &mut ap);
            let mut xm = x.clone();
            xm[i] -= h;
            let mut am = vec![0.0; p.aux_len()];
            p.init_aux(&xm, &mut am);
            let fd = (p.f_val(&xp, &ap) - p.f_val(&xm, &am)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i}: {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn block_grad_consistent() {
        let p = small();
        let x = vec![0.05; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut gfull = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut gfull);
        let mut gi = [0.0];
        for i in (0..p.n()).step_by(9) {
            p.block_grad(i, &x, &aux, &mut gi);
            assert!((gi[0] - gfull[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn flexa_drives_svm_merit_down() {
        use crate::coordinator::{flexa, CommonOptions, FlexaOptions, SelectionSpec, TermMetric};
        let p = small();
        let o = FlexaOptions {
            common: CommonOptions {
                max_iters: 3000,
                tol: 1e-4,
                term: TermMetric::Merit,
                merit_every: 1,
                name: "svm".into(),
                ..Default::default()
            },
            selection: SelectionSpec::sigma(0.5),
            inexact: None,
        };
        let r = flexa(&p, &vec![0.0; p.n()], &o);
        assert!(
            r.final_merit < 1e-3,
            "svm merit stalled at {} ({:?})",
            r.final_merit,
            r.stop
        );
        // training margins should classify most points after fitting
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&r.x, &mut aux);
        let correct = aux.iter().filter(|&&u| u > 0.0).count();
        assert!(correct * 10 > p.m() * 6, "only {correct}/{} correct", p.m());
    }

    #[test]
    fn incremental_margins_consistent() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(6);
        for _ in 0..30 {
            let i = rng.next_usize(p.n());
            let d = rng.next_normal() * 0.1;
            x[i] += d;
            p.apply_block_delta(i, &[d], &mut aux);
        }
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-9);
    }
}
