//! Problem abstraction: `V(x) = F(x) + G(x)` over a Cartesian product of
//! convex sets, with block-separable `G` (paper §II).
//!
//! The trait is designed around the paper's computational pattern:
//!
//! * every problem maintains an **auxiliary vector** (LASSO/nonconvex: the
//!   residual `r = Ax − b`; logistic: the label-scaled margins `u = Ỹx`)
//!   so that block gradients cost one column dot instead of a full matvec,
//!   and a selective update of `|S^k|` blocks costs `|S^k|` column axpys;
//! * the **best response** `x̂_i(x, τ)` of (4) is available in closed form
//!   for all four problem families (soft-threshold / block soft-threshold /
//!   damped-Newton soft-threshold / box-clamped soft-threshold);
//! * the error bound is the paper's default `E_i(x) = ‖x̂_i(x,τ) − x_i‖`
//!   (§IV), returned directly by `best_response`.
//!
//! All methods take `&self` plus explicit state so the coordinator can share
//! a problem across worker threads (`Problem: Send + Sync`).

pub mod dictionary;
pub mod group_lasso;
pub mod lasso;
pub mod logistic;
pub mod nonconvex_qp;
pub mod svm;

pub use dictionary::{dictionary_instance, solve_dictionary, DictOptions, DictReport};
pub use group_lasso::GroupLassoProblem;
pub use lasso::LassoProblem;
pub use logistic::LogisticProblem;
pub use nonconvex_qp::NonconvexQpProblem;
pub use svm::SvmProblem;

use crate::linalg::BlockPartition;
use std::ops::Range;

/// A column shard of a problem — the per-worker state of the
/// distributed-memory backend (`--backend sharded`): a contiguous block
/// range plus **copies of exactly those columns** of the data matrix.
/// No shard ever holds the full matrix; the engine hands each worker its
/// shard, the replicated auxiliary vector, and the shared per-iteration
/// scratch, and the worker computes best responses / delta columns for
/// its own blocks only (owner-computes).
///
/// Every method must use the same inner loops as the corresponding
/// full-matrix [`Problem`] method, so shard-computed quantities are
/// **bitwise identical** to the shared-memory backend — the golden-trace
/// suite (`tests/integration_golden.rs`) pins this end to end.
pub trait ProblemShard: Send + Sync {
    /// Global block range this shard owns.
    fn block_range(&self) -> Range<usize>;

    /// Fresh-state best response of owned block `i` (global index) into
    /// `out`; returns the error bound `E_i`. Mirrors
    /// [`Problem::best_response`] but reads only the shard's columns.
    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64;

    /// Scratch-assisted best response (logistic weights), defaulting to
    /// the fresh-state path. Mirrors [`Problem::best_response_with`].
    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.best_response(i, x, aux, tau, out)
    }

    /// Propagate an owned block's step into a residual-sized buffer
    /// (either the shard's partial delta buffer or a private auxiliary
    /// copy). Mirrors [`Problem::apply_block_delta`].
    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]);
}

/// A block-structured composite optimization problem.
pub trait Problem: Send + Sync {
    /// Total variable dimension `n`.
    fn n(&self) -> usize;

    /// Length of the maintained auxiliary vector.
    fn aux_len(&self) -> usize;

    /// Block partition of `x` (LASSO & friends: scalar blocks).
    fn blocks(&self) -> &BlockPartition;

    /// Recompute the auxiliary vector from scratch at `x`.
    fn init_aux(&self, x: &[f64], aux: &mut [f64]);

    /// Smooth part `F(x)` using the maintained `aux`.
    fn f_val(&self, x: &[f64], aux: &[f64]) -> f64;

    /// Nonsmooth part `G(x)`.
    fn g_val(&self, x: &[f64]) -> f64;

    /// Full objective `V(x) = F(x) + G(x)`.
    fn v_val(&self, x: &[f64], aux: &[f64]) -> f64 {
        self.f_val(x, aux) + self.g_val(x)
    }

    /// `∇_{x_i} F(x)` into `out` (length = block size).
    fn block_grad(&self, i: usize, x: &[f64], aux: &[f64], out: &mut [f64]);

    /// Best response `x̂_i(x, τ)` of subproblem (4) into `out`; returns the
    /// error bound `E_i(x) = ‖x̂_i − x_i‖`.
    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64;

    // ---- shared per-iteration scratch (optional fast path) ----

    /// Length of per-iteration shared scratch (logistic: 2m for the
    /// gradient/Hessian weights; quadratic problems: 0).
    fn prelude_len(&self) -> usize {
        0
    }

    /// Fill the shared scratch from the current iterate (computed once per
    /// outer iteration by the coordinator, shared by all blocks).
    fn prelude(&self, _x: &[f64], _aux: &[f64], _scratch: &mut [f64]) {}

    /// Best response using the shared scratch. Defaults to the fresh-state
    /// path; problems with an expensive per-sample transform (logistic)
    /// override this to reuse `scratch`.
    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.best_response(i, x, aux, tau, out)
    }

    /// Flops of one `prelude` call.
    fn flops_prelude(&self) -> f64 {
        0.0
    }

    /// Flops of a best response computed from *fresh* state (no shared
    /// scratch) — what the Gauss-Seidel sweeps of Algorithms 2/3 pay.
    fn flops_best_response_fresh(&self, i: usize) -> f64 {
        self.flops_best_response(i)
    }

    /// Propagate a block step to the auxiliary vector:
    /// `aux ← aux ⊕ (effect of x_i += delta)`. `delta` has block-size length.
    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]);

    /// Row-ranged [`Problem::apply_block_delta`]: apply the block-`i` delta
    /// to `aux_rows = aux[rows]` only. The pool-parallel selective update
    /// fans the aux rows out over fixed chunks, each chunk applying every
    /// selected block in order — per element this is the same addition
    /// order as the sequential path, so results stay bitwise identical.
    /// Every aux vector in this crate is row-indexed (residuals/margins),
    /// so all problems implement this as a ranged column axpy.
    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: Range<usize>,
    );

    // ---- chunked prelude / objective (pool-parallel fast paths) ----

    /// `Some((len_a, len_b))` when the prelude scratch splits into two
    /// equal-length row-indexed bands fillable per row range via
    /// [`Problem::prelude_rows`] (logistic: gradient and Hessian weights);
    /// `None` keeps the prelude sequential.
    fn prelude_bands(&self) -> Option<(usize, usize)> {
        None
    }

    /// Fill rows `rows` of each prelude band. The band slices are already
    /// offset: `band_a[k]` corresponds to row `rows.start + k`. Only
    /// called when [`Problem::prelude_bands`] returns `Some`.
    fn prelude_rows(
        &self,
        _x: &[f64],
        _aux: &[f64],
        _rows: Range<usize>,
        _band_a: &mut [f64],
        _band_b: &mut [f64],
    ) {
        unreachable!("prelude_rows requires prelude_bands() == Some");
    }

    /// Partial smooth objective over the aux rows `rows` (`aux_rows =
    /// aux[rows]`). Problems returning `true` from
    /// [`Problem::supports_chunked_obj`] must satisfy
    /// `Σ_chunks f_val_rows = f_val` up to floating-point reassociation.
    fn f_val_rows(&self, _x: &[f64], _aux_rows: &[f64], _rows: Range<usize>) -> f64 {
        0.0
    }

    /// Whether [`Problem::f_val_rows`] covers the full smooth objective
    /// (false for objectives with non-row terms, e.g. the −c̄‖x‖² of the
    /// nonconvex QP).
    fn supports_chunked_obj(&self) -> bool {
        false
    }

    /// Full gradient `∇F(x)` into `out` (for FISTA/SpaRSA and merits).
    fn grad_full(&self, x: &[f64], aux: &[f64], out: &mut [f64]);

    /// Proximal step for the baselines: `out = argmin_u 1/(2·step)‖u − v‖²
    /// + G(u) + δ_X(u)` — soft-threshold (+ box clamp where X is a box).
    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]);

    /// Stationarity merit (‖Z(x)‖∞ family of §VI); 0 iff stationary.
    fn merit(&self, x: &[f64], aux: &[f64]) -> f64;

    /// Paper's τ initialization (e.g. `tr(AᵀA)/2n`).
    fn tau_init(&self) -> f64;

    /// Lower bound on admissible τ (nonconvex problems: keeps subproblems
    /// strongly convex, paper §VI-C requires τ_i > c̄).
    fn tau_min(&self) -> f64 {
        0.0
    }

    /// Known optimal value, if the instance has one (Nesterov generator).
    fn v_star(&self) -> Option<f64> {
        None
    }

    /// Estimate of the Lipschitz constant of ∇F (FISTA step init).
    fn lipschitz(&self) -> f64;

    /// Upper bound on the block-`i` Lipschitz constant of `∇_i F` (the
    /// block curvature). Drives the importance-sampled selection strategy
    /// (`coordinator::strategy`): stiffer blocks are scanned more often.
    /// The default (uniform weights) makes importance sampling degrade
    /// gracefully to uniform sampling.
    fn block_lipschitz(&self, _i: usize) -> f64 {
        1.0
    }

    /// Build the column shard owning the given block range: copies of
    /// exactly those columns plus the per-block constants the best
    /// response needs — the per-worker data of the distributed-memory
    /// backend. `None` (the default) means the family has no sharded
    /// path yet (`--backend sharded` then refuses to run); the paper's
    /// three experimental families (LASSO, logistic, nonconvex QP)
    /// implement it.
    fn column_shard(&self, _blocks: Range<usize>) -> Option<Box<dyn ProblemShard>> {
        None
    }

    // ---- flop accounting (drives the cluster simulator) ----

    /// Flops for one best-response of block `i` (column dot + O(1)).
    fn flops_best_response(&self, i: usize) -> f64;

    /// Flops to propagate a block-`i` delta into `aux`.
    fn flops_aux_update(&self, i: usize) -> f64;

    /// Flops of a full gradient.
    fn flops_grad_full(&self) -> f64;

    /// Flops of one objective evaluation from maintained aux.
    fn flops_obj(&self) -> f64;
}

/// Relative error `re(x) = (V(x) − V*)/V*` (paper eq. 11); NaN if V* unknown.
pub fn relative_error(v: f64, v_star: Option<f64>) -> f64 {
    match v_star {
        Some(vs) if vs.abs() > 0.0 => (v - vs) / vs.abs(),
        _ => f64::NAN,
    }
}

/// Shared helper: ℓ1/box merit `‖Z̄(x)‖∞` where
/// `Z(x) = ∇F(x) − Π_{[-c,c]^n}(∇F(x) − x)` (paper §VI-B) and, when the
/// feasible set is a box `[-b, b]^n`, components that push outward at an
/// active bound are zeroed (paper §VI-C).
pub fn l1_merit_inf(grad: &[f64], x: &[f64], c: f64, box_bound: Option<f64>) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..x.len() {
        let z = grad[i] - (grad[i] - x[i]).clamp(-c, c);
        let zbar = match box_bound {
            Some(b) => {
                if (z <= 0.0 && x[i] >= b) || (z >= 0.0 && x[i] <= -b) {
                    0.0
                } else {
                    z
                }
            }
            None => z,
        };
        worst = worst.max(zbar.abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn relative_error_cases() {
        assert!((relative_error(2.0, Some(1.0)) - 1.0).abs() < 1e-15);
        assert!(relative_error(2.0, None).is_nan());
    }

    #[test]
    fn merit_zero_at_l1_stationarity() {
        // 1-D: F'(x) = -c, x > 0 is stationary for F + c|x| when F' = -c·sign
        // Z = g - clamp(g - x, -c, c); at x=1, g=-c: Z = -c - clamp(-c-1) = -c + c = 0
        let m = l1_merit_inf(&[-0.5], &[1.0], 0.5, None);
        assert!(m.abs() < 1e-15);
        // at x=0 with |g| <= c: Z = g - clamp(g, -c, c) = 0
        let m0 = l1_merit_inf(&[0.3], &[0.0], 0.5, None);
        assert!(m0.abs() < 1e-15);
        // non-stationary: x=0, |g| > c
        let m1 = l1_merit_inf(&[1.0], &[0.0], 0.5, None);
        assert!(m1 > 0.0);
    }

    #[test]
    fn merit_box_zeroing() {
        // gradient pushes outward at active upper bound -> zeroed
        let m = l1_merit_inf(&[-5.0], &[1.0], 0.5, Some(1.0));
        assert_eq!(m, 0.0);
        // pushes inward at bound -> not zeroed
        let m2 = l1_merit_inf(&[5.0], &[1.0], 0.5, Some(1.0));
        assert!(m2 > 0.0);
    }
}
