//! Problem abstraction: `V(x) = F(x) + G(x)` over a Cartesian product of
//! convex sets, with block-separable `G` (paper §II).
//!
//! The trait is designed around the paper's computational pattern:
//!
//! * every problem maintains an **auxiliary vector** (LASSO/nonconvex: the
//!   residual `r = Ax − b`; logistic: the label-scaled margins `u = Ỹx`)
//!   so that block gradients cost one column dot instead of a full matvec,
//!   and a selective update of `|S^k|` blocks costs `|S^k|` column axpys;
//! * the **best response** `x̂_i(x, τ)` of (4) is available in closed form
//!   for all four problem families (soft-threshold / block soft-threshold /
//!   damped-Newton soft-threshold / box-clamped soft-threshold);
//! * the error bound is the paper's default `E_i(x) = ‖x̂_i(x,τ) − x_i‖`
//!   (§IV), returned directly by `best_response`.
//!
//! All methods take `&self` plus explicit state so the coordinator can share
//! a problem across worker threads (`Problem: Send + Sync`).

pub mod dictionary;
pub mod group_lasso;
pub mod lasso;
pub mod logistic;
pub mod nonconvex_qp;
pub mod svm;

pub use dictionary::{
    dictionary_instance, solve_dictionary, DictOptions, DictReport, DictionaryCodesProblem,
    DictionaryInstance,
};
pub use group_lasso::GroupLassoProblem;
pub use lasso::LassoProblem;
pub use logistic::LogisticProblem;
pub use nonconvex_qp::NonconvexQpProblem;
pub use svm::SvmProblem;

use crate::linalg::{BlockPartition, NumericsTier};
use std::ops::Range;

/// A column shard of a problem — the per-worker state of the
/// distributed-memory backend (`--backend sharded`): a contiguous block
/// range plus **copies of exactly those columns** of the data matrix.
/// No shard ever holds the full matrix; the engine hands each worker its
/// shard, the replicated auxiliary vector, and the shared per-iteration
/// scratch, and the worker computes best responses / delta columns for
/// its own blocks only (owner-computes).
///
/// Every method must use the same inner loops as the corresponding
/// full-matrix [`Problem`] method, so shard-computed quantities are
/// **bitwise identical** to the shared-memory backend — the golden-trace
/// suite (`tests/integration_golden.rs`) pins this end to end.
pub trait ProblemShard: Send + Sync {
    /// Global block range this shard owns.
    fn block_range(&self) -> Range<usize>;

    /// Fresh-state best response of owned block `i` (global index) into
    /// `out`; returns the error bound `E_i`. Mirrors
    /// [`Problem::best_response`] but reads only the shard's columns.
    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64;

    /// Scratch-assisted best response (logistic weights), defaulting to
    /// the fresh-state path. Mirrors [`Problem::best_response_with`].
    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.best_response(i, x, aux, tau, out)
    }

    /// Numerics-tiered scratch-assisted best response. Defaults to the
    /// tier-less path (i.e. the exact kernels), which keeps every family
    /// without a fast-path override bitwise-identical across tiers;
    /// families whose scan is dominated by column reductions (LASSO,
    /// logistic) override this to route the column dots through
    /// [`crate::linalg::kernels`]. Mirrors
    /// [`Problem::best_response_with_tier`].
    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        scratch: &[f64],
        tau: f64,
        _tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        self.best_response_with(i, x, aux, scratch, tau, out)
    }

    /// Propagate an owned block's step into a residual-sized buffer
    /// (either the shard's partial delta buffer or a private auxiliary
    /// copy). Mirrors [`Problem::apply_block_delta`].
    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]);
}

/// A block-structured composite optimization problem.
pub trait Problem: Send + Sync {
    /// Total variable dimension `n`.
    fn n(&self) -> usize;

    /// Length of the maintained auxiliary vector.
    fn aux_len(&self) -> usize;

    /// Block partition of `x` (LASSO & friends: scalar blocks).
    fn blocks(&self) -> &BlockPartition;

    /// Recompute the auxiliary vector from scratch at `x`.
    fn init_aux(&self, x: &[f64], aux: &mut [f64]);

    /// Smooth part `F(x)` using the maintained `aux`.
    fn f_val(&self, x: &[f64], aux: &[f64]) -> f64;

    /// Nonsmooth part `G(x)`.
    fn g_val(&self, x: &[f64]) -> f64;

    /// Full objective `V(x) = F(x) + G(x)`.
    fn v_val(&self, x: &[f64], aux: &[f64]) -> f64 {
        self.f_val(x, aux) + self.g_val(x)
    }

    /// `∇_{x_i} F(x)` into `out` (length = block size).
    fn block_grad(&self, i: usize, x: &[f64], aux: &[f64], out: &mut [f64]);

    /// Best response `x̂_i(x, τ)` of subproblem (4) into `out`; returns the
    /// error bound `E_i(x) = ‖x̂_i − x_i‖`.
    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64;

    // ---- shared per-iteration scratch (optional fast path) ----

    /// Length of per-iteration shared scratch (logistic: 2m for the
    /// gradient/Hessian weights; quadratic problems: 0).
    fn prelude_len(&self) -> usize {
        0
    }

    /// Fill the shared scratch from the current iterate (computed once per
    /// outer iteration by the coordinator, shared by all blocks).
    fn prelude(&self, _x: &[f64], _aux: &[f64], _scratch: &mut [f64]) {}

    /// Best response using the shared scratch. Defaults to the fresh-state
    /// path; problems with an expensive per-sample transform (logistic)
    /// override this to reuse `scratch`.
    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        _scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        self.best_response(i, x, aux, tau, out)
    }

    /// Numerics-tiered best response using the shared scratch — what the
    /// pool-parallel Jacobi scans call ([`NumericsTier::Exact`] is the
    /// engine default and is bitwise-identical to
    /// [`Problem::best_response_with`]). The default ignores the tier, so
    /// families without a fast-path override stay bitwise-identical
    /// across tiers (a valid, documented fast tier); LASSO and logistic
    /// override it to route their column reductions through the tiered
    /// kernel layer ([`crate::linalg::kernels`]).
    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        aux: &[f64],
        scratch: &[f64],
        tau: f64,
        _tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        self.best_response_with(i, x, aux, scratch, tau, out)
    }

    /// Flops of one `prelude` call.
    fn flops_prelude(&self) -> f64 {
        0.0
    }

    /// Flops of a best response computed from *fresh* state (no shared
    /// scratch) — what the Gauss-Seidel sweeps of Algorithms 2/3 pay.
    fn flops_best_response_fresh(&self, i: usize) -> f64 {
        self.flops_best_response(i)
    }

    /// Propagate a block step to the auxiliary vector:
    /// `aux ← aux ⊕ (effect of x_i += delta)`. `delta` has block-size length.
    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]);

    /// Row-ranged [`Problem::apply_block_delta`]: apply the block-`i` delta
    /// to `aux_rows = aux[rows]` only. The pool-parallel selective update
    /// fans the aux rows out over fixed chunks, each chunk applying every
    /// selected block in order — per element this is the same addition
    /// order as the sequential path, so results stay bitwise identical.
    /// Every aux vector in this crate is row-indexed (residuals/margins),
    /// so all problems implement this as a ranged column axpy.
    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: Range<usize>,
    );

    // ---- chunked prelude / objective (pool-parallel fast paths) ----

    /// `Some((len_a, len_b))` when the prelude scratch splits into two
    /// equal-length row-indexed bands fillable per row range via
    /// [`Problem::prelude_rows`] (logistic: gradient and Hessian weights);
    /// `None` keeps the prelude sequential.
    fn prelude_bands(&self) -> Option<(usize, usize)> {
        None
    }

    /// Fill rows `rows` of each prelude band. The band slices are already
    /// offset: `band_a[k]` corresponds to row `rows.start + k`. Only
    /// called when [`Problem::prelude_bands`] returns `Some`.
    fn prelude_rows(
        &self,
        _x: &[f64],
        _aux: &[f64],
        _rows: Range<usize>,
        _band_a: &mut [f64],
        _band_b: &mut [f64],
    ) {
        unreachable!("prelude_rows requires prelude_bands() == Some");
    }

    /// Partial smooth objective over the aux rows `rows` (`aux_rows =
    /// aux[rows]`). Problems returning `true` from
    /// [`Problem::supports_chunked_obj`] must satisfy
    /// `Σ_chunks f_val_rows = f_val` up to floating-point reassociation.
    fn f_val_rows(&self, _x: &[f64], _aux_rows: &[f64], _rows: Range<usize>) -> f64 {
        0.0
    }

    /// Whether [`Problem::f_val_rows`] covers the full smooth objective
    /// (false for objectives with non-row terms, e.g. the −c̄‖x‖² of the
    /// nonconvex QP).
    fn supports_chunked_obj(&self) -> bool {
        false
    }

    /// Full gradient `∇F(x)` into `out` (for FISTA/SpaRSA and merits).
    fn grad_full(&self, x: &[f64], aux: &[f64], out: &mut [f64]);

    /// Proximal step for the baselines: `out = argmin_u 1/(2·step)‖u − v‖²
    /// + G(u) + δ_X(u)` — soft-threshold (+ box clamp where X is a box).
    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]);

    /// Stationarity merit (‖Z(x)‖∞ family of §VI); 0 iff stationary.
    fn merit(&self, x: &[f64], aux: &[f64]) -> f64;

    /// Paper's τ initialization (e.g. `tr(AᵀA)/2n`).
    fn tau_init(&self) -> f64;

    /// Lower bound on admissible τ (nonconvex problems: keeps subproblems
    /// strongly convex, paper §VI-C requires τ_i > c̄).
    fn tau_min(&self) -> f64 {
        0.0
    }

    /// Known optimal value, if the instance has one (Nesterov generator).
    fn v_star(&self) -> Option<f64> {
        None
    }

    /// Estimate of the Lipschitz constant of ∇F (FISTA step init).
    fn lipschitz(&self) -> f64;

    /// Upper bound on the block-`i` Lipschitz constant of `∇_i F` (the
    /// block curvature). Drives the importance-sampled selection strategy
    /// (`coordinator::strategy`): stiffer blocks are scanned more often.
    /// The default (uniform weights) makes importance sampling degrade
    /// gracefully to uniform sampling.
    fn block_lipschitz(&self, _i: usize) -> f64 {
        1.0
    }

    /// Row support of block `i` in the auxiliary vector — the set of aux
    /// rows that (a) `best_response(i, ..)` reads beyond `x[block i]`
    /// and (b) `apply_block_delta(i, ..)` writes. `Some(rows)` asserts
    /// this **locality contract**; `None` (the default) means the block
    /// may touch every aux row (dense data), which degenerates the
    /// dependency graph of `engine::depgraph` to the complete graph.
    /// Implementations must return ascending, duplicate-free indices.
    /// Only the *fresh-state* best response is covered by the contract —
    /// the prelude/scratch fast paths read global state and are not used
    /// on the dag schedule.
    fn block_rows(&self, _i: usize) -> Option<Vec<usize>> {
        None
    }

    /// Build the column shard owning the given block range: copies of
    /// exactly those columns plus the per-block constants the best
    /// response needs — the per-worker data of the distributed-memory
    /// backend. `None` (the default) means the family has no sharded
    /// path (`--backend sharded` then refuses to run). All six in-tree
    /// families (LASSO, group LASSO, logistic, ℓ2-SVM, nonconvex QP,
    /// dictionary sparse coding) implement it.
    fn column_shard(&self, _blocks: Range<usize>) -> Option<Box<dyn ProblemShard>> {
        None
    }

    /// Whether this family provides owner-computes column shards — the
    /// **single capability probe** behind every `backend = "sharded"`
    /// guard (CLI, config, engine), so supported-kind lists can never
    /// drift from the implementations again. Probes [`Problem::column_shard`]
    /// on the first block; the default is therefore correct for any impl.
    fn supports_column_shard(&self) -> bool {
        let nb = self.blocks().n_blocks();
        self.column_shard(0..nb.min(1)).is_some()
    }

    // ---- flop accounting (drives the cluster simulator) ----

    /// Flops for one best-response of block `i` (column dot + O(1)).
    fn flops_best_response(&self, i: usize) -> f64;

    /// Flops to propagate a block-`i` delta into `aux`.
    fn flops_aux_update(&self, i: usize) -> f64;

    /// Flops of a full gradient.
    fn flops_grad_full(&self) -> f64;

    /// Flops of one objective evaluation from maintained aux.
    fn flops_obj(&self) -> f64;
}

/// Whether `problem`'s smooth part is the plain residual sum of squares
/// `F(x) = ‖aux(x)‖²` at a point perturbed away from `base` — the
/// capability probe behind the ADMM splitting step (which assumes the
/// LASSO consensus form `min c‖x‖₁ + ‖s‖² s.t. Ax − s = b`). Probing at
/// a perturbed point keeps problems whose extra objective terms vanish
/// at `base` (e.g. the −c̄‖x‖² of the nonconvex QP at 0) from slipping
/// through. The CLI guard and the engine's runtime assert both call
/// this, so the two surfaces cannot drift.
pub fn is_residual_form_at(problem: &dyn Problem, base: &[f64]) -> bool {
    let mut xp = base.to_vec();
    if !xp.is_empty() {
        xp[0] += 0.5;
    }
    let mut auxp = vec![0.0; problem.aux_len()];
    problem.init_aux(&xp, &mut auxp);
    let f = problem.f_val(&xp, &auxp);
    let ssq: f64 = auxp.iter().map(|r| r * r).sum();
    (f - ssq).abs() <= 1e-8 * ssq.abs().max(1.0)
}

/// [`is_residual_form_at`] probed from the origin.
pub fn is_residual_form(problem: &dyn Problem) -> bool {
    let origin = vec![0.0; problem.n()];
    is_residual_form_at(problem, &origin)
}

/// Relative error `re(x) = (V(x) − V*)/V*` (paper eq. 11); NaN if V* unknown.
pub fn relative_error(v: f64, v_star: Option<f64>) -> f64 {
    match v_star {
        Some(vs) if vs.abs() > 0.0 => (v - vs) / vs.abs(),
        _ => f64::NAN,
    }
}

/// Shared helper: ℓ1/box merit `‖Z̄(x)‖∞` where
/// `Z(x) = ∇F(x) − Π_{[-c,c]^n}(∇F(x) − x)` (paper §VI-B) and, when the
/// feasible set is a box `[-b, b]^n`, components that push outward at an
/// active bound are zeroed (paper §VI-C).
pub fn l1_merit_inf(grad: &[f64], x: &[f64], c: f64, box_bound: Option<f64>) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..x.len() {
        let z = grad[i] - (grad[i] - x[i]).clamp(-c, c);
        let zbar = match box_bound {
            Some(b) => {
                if (z <= 0.0 && x[i] >= b) || (z >= 0.0 && x[i] <= -b) {
                    0.0
                } else {
                    z
                }
            }
            None => z,
        };
        worst = worst.max(zbar.abs());
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_form_probe_separates_the_families() {
        use crate::datagen::{logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset};
        let lasso = LassoProblem::from_instance(nesterov_lasso(20, 30, 0.2, 1.0, 1));
        assert!(is_residual_form(&lasso));
        let group = GroupLassoProblem::from_instance(nesterov_lasso(20, 24, 0.2, 1.0, 1), 4);
        assert!(is_residual_form(&group));
        let dict =
            DictionaryCodesProblem::from_instance(&dictionary_instance(8, 5, 9, 0.3, 0.01, 1));
        assert!(is_residual_form(&dict));
        let logistic =
            LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.01, 1));
        assert!(!is_residual_form(&logistic));
        let svm_inst = logistic_like(LogisticPreset::Gisette, 0.01, 2);
        let svm = SvmProblem::new(svm_inst.y, &svm_inst.labels, 0.25);
        assert!(!is_residual_form(&svm));
        let qp = NonconvexQpProblem::from_instance(nonconvex_qp(20, 30, 0.2, 10.0, 50.0, 1.0, 1));
        assert!(!is_residual_form(&qp));
    }

    #[test]
    fn every_family_reports_column_shard_support() {
        use crate::datagen::{logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset};
        let svm_inst = logistic_like(LogisticPreset::Gisette, 0.01, 3);
        let problems: Vec<Box<dyn Problem>> = vec![
            Box::new(LassoProblem::from_instance(nesterov_lasso(20, 30, 0.2, 1.0, 1))),
            Box::new(GroupLassoProblem::from_instance(nesterov_lasso(20, 24, 0.2, 1.0, 1), 4)),
            Box::new(LogisticProblem::from_instance(logistic_like(
                LogisticPreset::Gisette,
                0.01,
                1,
            ))),
            Box::new(SvmProblem::new(svm_inst.y, &svm_inst.labels, 0.25)),
            Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
                20, 30, 0.2, 10.0, 50.0, 1.0, 1,
            ))),
            Box::new(DictionaryCodesProblem::from_instance(&dictionary_instance(
                8, 5, 9, 0.3, 0.01, 1,
            ))),
        ];
        for p in &problems {
            assert!(p.supports_column_shard());
        }
    }

    #[test]
    fn relative_error_cases() {
        assert!((relative_error(2.0, Some(1.0)) - 1.0).abs() < 1e-15);
        assert!(relative_error(2.0, None).is_nan());
    }

    #[test]
    fn merit_zero_at_l1_stationarity() {
        // 1-D: F'(x) = -c, x > 0 is stationary for F + c|x| when F' = -c·sign
        // Z = g - clamp(g - x, -c, c); at x=1, g=-c: Z = -c - clamp(-c-1) = -c + c = 0
        let m = l1_merit_inf(&[-0.5], &[1.0], 0.5, None);
        assert!(m.abs() < 1e-15);
        // at x=0 with |g| <= c: Z = g - clamp(g, -c, c) = 0
        let m0 = l1_merit_inf(&[0.3], &[0.0], 0.5, None);
        assert!(m0.abs() < 1e-15);
        // non-stationary: x=0, |g| > c
        let m1 = l1_merit_inf(&[1.0], &[0.0], 0.5, None);
        assert!(m1 > 0.0);
    }

    #[test]
    fn merit_box_zeroing() {
        // gradient pushes outward at active upper bound -> zeroed
        let m = l1_merit_inf(&[-5.0], &[1.0], 0.5, Some(1.0));
        assert_eq!(m, 0.0);
        // pushes inward at bound -> not zeroed
        let m2 = l1_merit_inf(&[5.0], &[1.0], 0.5, Some(1.0));
        assert!(m2 > 0.0);
    }
}
