//! Sparse logistic regression: `min Σ_j log(1 + e^{−a_j y_jᵀ x}) + c‖x‖₁`
//! (paper §II, §VI-B; Fig. 3, Table I).
//!
//! Scalar blocks. We fold the labels into the data at construction,
//! `Ỹ_{ji} = a_j Y_{ji}`, so the auxiliary state is the margin vector
//! `u = Ỹ x` and
//!
//! * `F(x) = Σ_j log1p(e^{−u_j})`;
//! * `∇F(x) = −Ỹᵀ σ(−u)` with `σ(s) = 1/(1+e^{−s})`;
//! * the paper's approximant (Example #3) is the **second-order** expansion
//!   of `F(x_i, x_{−i}^k)`: with `g_i = ∇_i F` and
//!   `h_i = Σ_j Ỹ_{ji}² σ(−u_j)σ(u_j)` (Hessian diagonal),
//!   `x̂_i = ST(x_i − g_i/(h_i + τ), c/(h_i + τ))` — a damped Newton step
//!   through the soft threshold, computable in closed form.
//!
//! The per-iteration weights `w_j = σ(−u_j)` and `q_j = w_j(1−w_j)` are
//! shared by all blocks, so the coordinator computes them once per outer
//! iteration via [`LogisticProblem::weights_into`] (this is the "extra
//! calculations for the latest information" trade-off the paper discusses
//! for Gauss-Seidel variants — the cost model charges for it).

use super::{Problem, ProblemShard};
use crate::datagen::LogisticInstance;
use crate::linalg::{kernels, vector, BlockPartition, Matrix, NumericsTier};

/// ℓ1-regularized logistic regression with maintained margins.
pub struct LogisticProblem {
    /// label-scaled data `Ỹ` (m×n)
    y: Matrix,
    c: f64,
    blocks: BlockPartition,
    /// squared column norms `‖Ỹ_i‖²` (per-block curvature bounds /4)
    col_sq: Vec<f64>,
    lipschitz: f64,
    name: String,
    /// optional reference value for re(x) plots (estimated offline)
    v_star: Option<f64>,
}

/// Numerically-stable `log(1 + e^{−u})`.
#[inline]
pub fn log1p_exp_neg(u: f64) -> f64 {
    if u > 0.0 {
        (-u).exp().ln_1p()
    } else {
        -u + u.exp().ln_1p()
    }
}

/// Stable `σ(−u) = 1/(1+e^{u})` (canonical body lives in the kernel
/// layer so the margin-weight pass shares one definition).
#[inline]
pub fn sigma_neg(u: f64) -> f64 {
    kernels::sigma_neg(u)
}

impl LogisticProblem {
    /// Build from raw data: `y` is m×n (rows = samples), labels in {−1,+1}.
    pub fn new(mut y: Matrix, labels: &[f64], c: f64, name: impl Into<String>) -> Self {
        assert_eq!(y.nrows(), labels.len());
        assert!(c > 0.0);
        // fold labels into rows: Ỹ = diag(a) Y. Column-major storage means
        // per-row scaling is a strided pass; do it via dense/sparse cases.
        match &mut y {
            Matrix::Dense(d) => {
                for j in 0..d.ncols() {
                    let col = d.col_mut(j);
                    for (i, v) in col.iter_mut().enumerate() {
                        *v *= labels[i];
                    }
                }
            }
            Matrix::Sparse(_) => {
                // rebuild triplets with scaled values
                let dense_equiv = None::<()>;
                let _ = dense_equiv;
                y = scale_sparse_rows(y, labels);
            }
        }
        let n = y.ncols();
        // L_∇F = λmax(ỸᵀỸ)/4 ≤ tr(ỸᵀỸ)/4 (cheap, safe upper bound)
        let lipschitz = y.gram_trace() / 4.0;
        let col_sq = y.col_sq_norms();
        Self {
            y,
            c,
            blocks: BlockPartition::scalar(n),
            col_sq,
            lipschitz,
            name: name.into(),
            v_star: None,
        }
    }

    /// Build from a generated dataset analog.
    pub fn from_instance(inst: LogisticInstance) -> Self {
        let name = inst.name.clone();
        Self::new(inst.y, &inst.labels, inst.c, name)
    }

    /// Attach a reference optimal value (paper §VI-B estimates V* by running
    /// GJ-FLEXA to ‖Z‖∞ ≤ 1e−7 first).
    pub fn set_v_star(&mut self, v: f64) {
        self.v_star = Some(v);
    }

    /// ℓ1 weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Dataset name (plots, tables).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Number of samples m.
    pub fn m(&self) -> usize {
        self.y.nrows()
    }

    /// The label-scaled data matrix `Ỹ`.
    pub fn matrix(&self) -> &Matrix {
        &self.y
    }

    /// Compute the shared per-sample weights from the margins:
    /// `w_j = σ(−u_j)` (gradient weights), `q_j = w_j(1−w_j)` (Hessian).
    pub fn weights_into(&self, aux: &[f64], w: &mut [f64], q: &mut [f64]) {
        debug_assert_eq!(aux.len(), w.len());
        debug_assert_eq!(aux.len(), q.len());
        kernels::logistic_weights(aux, w, q);
    }

    /// Best response given precomputed weights (the coordinator's fast path;
    /// `best_response` below recomputes weights for trait-level correctness).
    pub fn best_response_weighted(
        &self,
        i: usize,
        x: &[f64],
        w: &[f64],
        q: &[f64],
        tau: f64,
    ) -> f64 {
        let g = -self.y.col_dot(i, w);
        let h = self.y.col_sq_weighted_dot(i, q);
        let denom = h + tau;
        vector::soft_threshold(x[i] - g / denom, self.c / denom)
    }

    /// Flops of the shared weight pass (exp ≈ 4 flops each).
    pub fn flops_weights(&self) -> f64 {
        6.0 * self.m() as f64
    }
}

fn scale_sparse_rows(y: Matrix, labels: &[f64]) -> Matrix {
    match y {
        Matrix::Sparse(s) => {
            let (m, n) = (s.nrows(), s.ncols());
            let mut triplets = Vec::with_capacity(s.nnz());
            for j in 0..n {
                let (rows, vals) = s.col(j);
                for (&i, &v) in rows.iter().zip(vals) {
                    triplets.push((i, j, v * labels[i]));
                }
            }
            Matrix::Sparse(crate::linalg::CscMatrix::from_triplets(m, n, &triplets))
        }
        other => other,
    }
}

impl Problem for LogisticProblem {
    fn n(&self) -> usize {
        self.y.ncols()
    }

    fn aux_len(&self) -> usize {
        self.y.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.y.matvec(x, aux);
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        aux.iter().map(|&u| log1p_exp_neg(u)).sum()
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.c * vector::nrm1(x)
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        // ∇_i F = −Σ_j Ỹ_{ji} σ(−u_j); recompute weights locally (trait
        // path; the coordinator uses `best_response_weighted`)
        let mut acc = 0.0;
        match &self.y {
            Matrix::Dense(d) => {
                let col = d.col(i);
                for (v, &u) in col.iter().zip(aux) {
                    acc += v * sigma_neg(u);
                }
            }
            Matrix::Sparse(s) => {
                let (rows, vals) = s.col(i);
                for (&r, &v) in rows.iter().zip(vals) {
                    acc += v * sigma_neg(aux[r]);
                }
            }
        }
        out[0] = -acc;
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let (mut g, mut h) = (0.0, 0.0);
        match &self.y {
            Matrix::Dense(d) => {
                let col = d.col(i);
                for (v, &u) in col.iter().zip(aux) {
                    let s = sigma_neg(u);
                    g -= v * s;
                    h += v * v * s * (1.0 - s);
                }
            }
            Matrix::Sparse(sp) => {
                let (rows, vals) = sp.col(i);
                for (&r, &v) in rows.iter().zip(vals) {
                    let s = sigma_neg(aux[r]);
                    g -= v * s;
                    h += v * v * s * (1.0 - s);
                }
            }
        }
        let denom = h + tau;
        debug_assert!(denom > 0.0);
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn prelude_len(&self) -> usize {
        2 * self.m()
    }

    fn prelude(&self, _x: &[f64], aux: &[f64], scratch: &mut [f64]) {
        let m = self.m();
        let (w, q) = scratch.split_at_mut(m);
        self.weights_into(aux, w, q);
    }

    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        _aux: &[f64],
        scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        let m = self.m();
        let (w, q) = scratch.split_at(m);
        let z = self.best_response_weighted(i, x, w, q, tau);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        _aux: &[f64],
        scratch: &[f64],
        tau: f64,
        tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        let m = self.m();
        let (w, q) = scratch.split_at(m);
        let g = -self.y.col_dot_with(tier, i, w);
        let h = self.y.col_sq_weighted_dot_with(tier, i, q);
        let denom = h + tau;
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn flops_prelude(&self) -> f64 {
        self.flops_weights()
    }

    fn flops_best_response_fresh(&self, i: usize) -> f64 {
        // per stored entry: exp (≈4) + sigma + g and h accumulation
        9.0 * self.y.col_nnz(i) as f64 + 8.0
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.y.col_axpy(i, delta[0], aux);
        }
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        if delta[0] != 0.0 {
            self.y.col_axpy_range(i, delta[0], aux_rows, rows);
        }
    }

    fn prelude_bands(&self) -> Option<(usize, usize)> {
        Some((self.m(), self.m()))
    }

    fn prelude_rows(
        &self,
        _x: &[f64],
        aux: &[f64],
        rows: std::ops::Range<usize>,
        band_a: &mut [f64],
        band_b: &mut [f64],
    ) {
        for (k, j) in rows.enumerate() {
            let s = sigma_neg(aux[j]);
            band_a[k] = s;
            band_b[k] = s * (1.0 - s);
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        aux_rows.iter().map(|&u| log1p_exp_neg(u)).sum()
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        let w: Vec<f64> = aux.iter().map(|&u| sigma_neg(u)).collect();
        self.y.matvec_t(&w, out);
        vector::scale(-1.0, out);
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        vector::soft_threshold_vec(v, step * self.c, out);
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        // paper §VI-B: ‖Z(x)‖∞ with Z = ∇F − Π_{[-c,c]^n}(∇F − x)
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        super::l1_merit_inf(&g, x, self.c, None)
    }

    fn tau_init(&self) -> f64 {
        // paper §VI-B: τ_i = tr(YᵀY)/2n
        self.y.gram_trace() / (2.0 * self.n() as f64)
    }

    fn v_star(&self) -> Option<f64> {
        self.v_star
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // scalar blocks: h_i = Σ_j Ỹ_{ji}² σσ' ≤ ‖Ỹ_i‖²/4
        self.col_sq[i] / 4.0
    }

    fn block_rows(&self, i: usize) -> Option<Vec<usize>> {
        // scalar blocks: the fresh-state best_response(i) reads margins
        // only on column i's row support and apply_block_delta writes
        // those same rows (one col_axpy). The weighted prelude fast path
        // reads global weights and is NOT covered — the dag schedule
        // always uses the fresh-state path.
        self.y.col_rows(i).map(|r| r.to_vec())
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // scalar blocks: block index == column index
        Some(Box::new(LogisticShard {
            y: self.y.columns_range(blocks.clone()),
            c: self.c,
            blocks,
        }))
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        // fast path: two fused column passes over precomputed weights
        4.0 * self.y.col_nnz(i) as f64 + 8.0
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.y.col_nnz(i) as f64
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.y.nnz() as f64 + self.flops_weights()
    }

    fn flops_obj(&self) -> f64 {
        5.0 * self.aux_len() as f64 + 2.0 * self.n() as f64
    }
}

/// Column shard of a [`LogisticProblem`]: the owned scalar blocks'
/// label-scaled columns. Both best-response paths (weighted fast path
/// from the shared prelude scratch, fresh-state recompute) mirror the
/// full problem's inner loops exactly, so results are bitwise equal.
struct LogisticShard {
    /// The shard's label-scaled columns `Ỹ_s` (m × |blocks|).
    y: Matrix,
    /// ℓ1 weight `c`.
    c: f64,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for LogisticShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let (mut g, mut h) = (0.0, 0.0);
        match &self.y {
            Matrix::Dense(d) => {
                let col = d.col(i - self.blocks.start);
                for (v, &u) in col.iter().zip(aux) {
                    let s = sigma_neg(u);
                    g -= v * s;
                    h += v * v * s * (1.0 - s);
                }
            }
            Matrix::Sparse(sp) => {
                let (rows, vals) = sp.col(i - self.blocks.start);
                for (&r, &v) in rows.iter().zip(vals) {
                    let s = sigma_neg(aux[r]);
                    g -= v * s;
                    h += v * v * s * (1.0 - s);
                }
            }
        }
        let denom = h + tau;
        debug_assert!(denom > 0.0);
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn best_response_with(
        &self,
        i: usize,
        x: &[f64],
        _aux: &[f64],
        scratch: &[f64],
        tau: f64,
        out: &mut [f64],
    ) -> f64 {
        let m = self.y.nrows();
        let (w, q) = scratch.split_at(m);
        let j = i - self.blocks.start;
        let g = -self.y.col_dot(j, w);
        let h = self.y.col_sq_weighted_dot(j, q);
        let denom = h + tau;
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn best_response_with_tier(
        &self,
        i: usize,
        x: &[f64],
        _aux: &[f64],
        scratch: &[f64],
        tau: f64,
        tier: NumericsTier,
        out: &mut [f64],
    ) -> f64 {
        let m = self.y.nrows();
        let (w, q) = scratch.split_at(m);
        let j = i - self.blocks.start;
        let g = -self.y.col_dot_with(tier, j, w);
        let h = self.y.col_sq_weighted_dot_with(tier, j, q);
        let denom = h + tau;
        let z = vector::soft_threshold(x[i] - g / denom, self.c / denom);
        out[0] = z;
        (z - x[i]).abs()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        if delta[0] != 0.0 {
            self.y.col_axpy(i - self.blocks.start, delta[0], aux);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{logistic_like, LogisticPreset};

    fn small() -> LogisticProblem {
        LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.01, 77))
    }

    #[test]
    fn column_shard_matches_full_problem_bitwise() {
        // both the sparse (real-sim-like) and dense (gisette-like) storages
        for p in [
            small(),
            LogisticProblem::from_instance(logistic_like(LogisticPreset::RealSim, 0.005, 31)),
        ] {
            let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(5);
            let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.2).collect();
            let mut aux = vec![0.0; p.aux_len()];
            p.init_aux(&x, &mut aux);
            let mut scratch = vec![0.0; p.prelude_len()];
            p.prelude(&x, &aux, &mut scratch);
            let lo = p.n() / 3;
            let hi = 2 * p.n() / 3;
            let shard = p.column_shard(lo..hi).expect("logistic shards");
            let (mut zf, mut zs) = ([0.0], [0.0]);
            for i in lo..hi {
                let ef = p.best_response(i, &x, &aux, 0.9, &mut zf);
                let es = shard.best_response(i, &x, &aux, 0.9, &mut zs);
                assert_eq!(ef, es, "fresh E_{i}");
                assert_eq!(zf[0], zs[0], "fresh zhat_{i}");
                let ef = p.best_response_with(i, &x, &aux, &scratch, 0.9, &mut zf);
                let es = shard.best_response_with(i, &x, &aux, &scratch, 0.9, &mut zs);
                assert_eq!(ef, es, "weighted E_{i}");
                assert_eq!(zf[0], zs[0], "weighted zhat_{i}");
            }
        }
    }

    #[test]
    fn stable_scalar_helpers() {
        assert!((log1p_exp_neg(0.0) - (2.0f64).ln()).abs() < 1e-12);
        assert!(log1p_exp_neg(800.0) < 1e-300); // no overflow
        assert!(log1p_exp_neg(-800.0) > 799.0); // ≈ −u
        assert!((sigma_neg(0.0) - 0.5).abs() < 1e-12);
        assert!(sigma_neg(800.0) < 1e-300);
        assert!((sigma_neg(-800.0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn grad_matches_finite_differences() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(1);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.2).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut g = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut g);
        let h = 1e-6;
        for i in [0, 3, p.n() - 1] {
            let mut xp = x.clone();
            xp[i] += h;
            let mut ap = vec![0.0; p.aux_len()];
            p.init_aux(&xp, &mut ap);
            let mut xm = x.clone();
            xm[i] -= h;
            let mut am = vec![0.0; p.aux_len()];
            p.init_aux(&xm, &mut am);
            let fd = (p.f_val(&xp, &ap) - p.f_val(&xm, &am)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-5, "i={i}: fd={fd} vs g={}", g[i]);
        }
    }

    #[test]
    fn block_grad_consistent_with_full() {
        let p = small();
        let x = vec![0.1; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut gfull = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut gfull);
        let mut gi = [0.0];
        for i in (0..p.n()).step_by(7) {
            p.block_grad(i, &x, &aux, &mut gi);
            assert!((gi[0] - gfull[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn weighted_fast_path_matches_trait_path() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(2);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.1).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut w = vec![0.0; p.aux_len()];
        let mut q = vec![0.0; p.aux_len()];
        p.weights_into(&aux, &mut w, &mut q);
        for i in (0..p.n()).step_by(11) {
            let fast = p.best_response_weighted(i, &x, &w, &q, 0.9);
            let mut z = [0.0];
            p.best_response(i, &x, &aux, 0.9, &mut z);
            assert!((fast - z[0]).abs() < 1e-12);
        }
    }

    #[test]
    fn best_response_decreases_model_and_is_descent() {
        // The damped Newton + soft threshold step must not increase the true
        // objective by much for a small relax factor; check V decrease along
        // the direction (Prop. 8c is about the full direction; here scalar).
        let p = small();
        let x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let v0 = p.v_val(&x, &aux);
        // take one full-Jacobi best-response step with gamma = 0.1
        let mut xn = x.clone();
        let mut z = [0.0];
        for i in 0..p.n() {
            p.best_response(i, &x, &aux, p.tau_init(), &mut z);
            xn[i] = x[i] + 0.1 * (z[0] - x[i]);
        }
        let mut auxn = vec![0.0; p.aux_len()];
        p.init_aux(&xn, &mut auxn);
        let v1 = p.v_val(&xn, &auxn);
        assert!(v1 <= v0 + 1e-9, "V increased: {v0} -> {v1}");
    }

    #[test]
    fn incremental_margins_match_recompute() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(4);
        for _ in 0..40 {
            let i = rng.next_usize(p.n());
            let d = rng.next_normal() * 0.1;
            x[i] += d;
            p.apply_block_delta(i, &[d], &mut aux);
        }
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-9);
    }

    #[test]
    fn sparse_instance_works() {
        let p = LogisticProblem::from_instance(logistic_like(LogisticPreset::RealSim, 0.005, 31));
        assert!(p.matrix().is_sparse());
        let x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        // F(0) = m·log 2
        let expect = p.aux_len() as f64 * (2.0f64).ln();
        assert!((p.f_val(&x, &aux) - expect).abs() < 1e-8);
        let mut z = [0.0];
        let e = p.best_response(0, &x, &aux, p.tau_init(), &mut z);
        assert!(e.is_finite());
    }
}
