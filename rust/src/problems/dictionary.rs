//! Dictionary learning for sparse representation (paper §II, sixth bullet;
//! §IV Example #4):
//!
//! ```text
//! min  ‖Y − D S‖²_F + c‖S‖₁    s.t.  ‖D e_i‖² ≤ α_i  ∀i
//! ```
//!
//! with dictionary `D ∈ R^{d×k}` and codes `S ∈ R^{k×N}`. `F` is *not
//! jointly convex* in `(D, S)` — the two-matrix-block nonconvex showcase of
//! the framework. Following Example #4 we use the **linearized**
//! approximants `P_1/P_2` (gradient at the current pair), which give
//! closed-form best responses:
//!
//! * D-block: gradient step + per-column ball projection
//!   `D̂ = Π_α( D − ∇_D F/(L_D + τ) )`;
//! * S-block: gradient step + soft threshold
//!   `Ŝ = ST( S − ∇_S F/(L_S + τ), c/(L_S + τ) )`.
//!
//! This is a standalone alternating-FLEXA driver (two giant blocks with
//! inner structure rather than the scalar-block `Problem` trait: the
//! framework's "degree of parallelism" here lives *inside* each matrix
//! block, matching the paper's description).

use crate::linalg::{vector, DenseMatrix};
use crate::metrics::Trace;
use crate::rng::Xoshiro256pp;
use crate::util::Timer;

/// A dictionary-learning instance: observations `Y ≈ D* S*`.
#[derive(Clone, Debug)]
pub struct DictionaryInstance {
    /// observed data Y (m×q)
    pub y: DenseMatrix,
    /// ℓ1 weight on the codes
    pub c: f64,
    /// column-norm bounds α_i (uniform here)
    pub alpha: f64,
    /// ground-truth dictionary D (m×r)
    pub d_true: DenseMatrix,
    /// ground-truth sparse codes S (r×q)
    pub s_true: DenseMatrix,
}

/// Generate observations from a random unit-norm dictionary and sparse codes.
pub fn dictionary_instance(
    d_rows: usize,
    k_atoms: usize,
    n_samples: usize,
    code_sparsity: f64,
    noise: f64,
    seed: u64,
) -> DictionaryInstance {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut d = DenseMatrix::zeros(d_rows, k_atoms);
    for j in 0..k_atoms {
        let col = d.col_mut(j);
        rng.fill_normal(col);
        let nrm = vector::nrm2(col);
        vector::scale(1.0 / nrm, col);
    }
    let mut s = DenseMatrix::zeros(k_atoms, n_samples);
    let nnz_per_col = ((k_atoms as f64 * code_sparsity).ceil() as usize).max(1);
    for j in 0..n_samples {
        for &i in &rng.choose_k(k_atoms, nnz_per_col) {
            s.set(i, j, rng.next_normal());
        }
    }
    // Y = D S + noise
    let mut y = DenseMatrix::zeros(d_rows, n_samples);
    matmul_into(&d, &s, &mut y);
    for j in 0..n_samples {
        for v in y.col_mut(j) {
            *v += noise * rng.next_normal();
        }
    }
    DictionaryInstance { y, c: 0.1, alpha: 1.0, d_true: d, s_true: s }
}

/// `out = A·B` (column-major, small matrices — the substrate for this
/// problem only; the big solvers never need dense matmul).
pub fn matmul_into(a: &DenseMatrix, b: &DenseMatrix, out: &mut DenseMatrix) {
    assert_eq!(a.ncols(), b.nrows());
    assert_eq!(out.nrows(), a.nrows());
    assert_eq!(out.ncols(), b.ncols());
    for j in 0..b.ncols() {
        let out_col = out.col_mut(j);
        out_col.fill(0.0);
        for l in 0..a.ncols() {
            let blj = b.get(l, j);
            if blj != 0.0 {
                vector::axpy(blj, a.col(l), out_col);
            }
        }
    }
}

/// Options for the alternating FLEXA dictionary solver.
#[derive(Clone, Copy, Debug)]
pub struct DictOptions {
    /// outer-iteration budget
    pub max_iters: usize,
    /// objective-decrease stopping tolerance
    pub tol: f64,
    /// initial step size γ0
    pub gamma0: f64,
    /// step-size decay θ of rule (6)
    pub theta: f64,
    /// proximal weight τ
    pub tau: f64,
}

impl Default for DictOptions {
    fn default() -> Self {
        Self { max_iters: 500, tol: 1e-5, gamma0: 0.9, theta: 1e-4, tau: 1e-3 }
    }
}

/// Result of a dictionary-learning run.
pub struct DictReport {
    /// learned dictionary
    pub d: DenseMatrix,
    /// learned sparse codes
    pub s: DenseMatrix,
    /// final objective value
    pub objective: f64,
    /// outer iterations executed
    pub iters: usize,
    /// objective trace
    pub trace: Trace,
    /// whether the objective-decrease tolerance was reached
    pub converged: bool,
}

/// Alternating FLEXA (Example #4): both matrix blocks take linearized best
/// responses simultaneously (Jacobi across the two blocks), combined with
/// the diminishing-γ memory step of Algorithm 1.
pub fn solve_dictionary(inst: &DictionaryInstance, opts: &DictOptions) -> DictReport {
    let (dr, k) = (inst.y.nrows(), inst.d_true.ncols());
    let ns = inst.y.ncols();
    let mut rng = Xoshiro256pp::seed_from_u64(0xD1C7);

    // init: random unit dictionary, zero codes
    let mut d = DenseMatrix::zeros(dr, k);
    for j in 0..k {
        let col = d.col_mut(j);
        rng.fill_normal(col);
        let nrm = vector::nrm2(col);
        vector::scale(1.0 / nrm, col);
    }
    let mut s = DenseMatrix::zeros(k, ns);

    // workspaces
    let mut resid = DenseMatrix::zeros(dr, ns); // DS − Y
    let mut gd = DenseMatrix::zeros(dr, k); // ∇_D F = 2 R Sᵀ
    let mut gs = DenseMatrix::zeros(k, ns); // ∇_S F = 2 Dᵀ R
    let mut d_hat = DenseMatrix::zeros(dr, k);
    let mut s_hat = DenseMatrix::zeros(k, ns);

    let mut gamma = opts.gamma0;
    let timer = Timer::start();
    let mut trace = Trace::new("dict-FLEXA");
    let mut iters = 0;
    let mut converged = false;
    let mut obj = f64::INFINITY;

    for kiter in 0..opts.max_iters {
        iters = kiter + 1;
        // residual R = DS − Y and objective
        matmul_into(&d, &s, &mut resid);
        for j in 0..ns {
            for (r, yv) in resid.col_mut(j).iter_mut().zip(inst.y.col(j)) {
                *r -= yv;
            }
        }
        obj = resid.fro_norm().powi(2) + inst.c * vector::nrm1(s.data());

        // block Lipschitz constants (spectral upper bounds via traces)
        let l_d = 2.0 * s.fro_norm().powi(2) + opts.tau;
        let l_s = 2.0 * d.fro_norm().powi(2) + opts.tau;

        // ∇_D F = 2 R Sᵀ  (column l of gd = 2 Σ_j R_col_j · S_{l,j})
        for l in 0..k {
            let col = gd.col_mut(l);
            col.fill(0.0);
            for j in 0..ns {
                let slj = s.get(l, j);
                if slj != 0.0 {
                    vector::axpy(2.0 * slj, resid.col(j), col);
                }
            }
        }
        // ∇_S F = 2 Dᵀ R
        for j in 0..ns {
            for l in 0..k {
                gs.set(l, j, 2.0 * vector::dot(d.col(l), resid.col(j)));
            }
        }

        // best responses (linearized + prox / projection)
        for l in 0..k {
            let dl = d.col(l);
            let gl = gd.col(l);
            let hat = d_hat.col_mut(l);
            for i in 0..dr {
                hat[i] = dl[i] - gl[i] / l_d;
            }
            // project onto the α-ball
            let nrm = vector::nrm2(hat);
            if nrm * nrm > inst.alpha {
                vector::scale(inst.alpha.sqrt() / nrm, hat);
            }
        }
        let thr = inst.c / l_s;
        let mut step = 0.0f64;
        for j in 0..ns {
            for l in 0..k {
                let cur = s.get(l, j);
                let z = vector::soft_threshold(cur - gs.get(l, j) / l_s, thr);
                s_hat.set(l, j, z);
                step = step.max((z - cur).abs());
            }
        }
        for l in 0..k {
            for i in 0..dr {
                step = step.max((d_hat.get(i, l) - d.get(i, l)).abs());
            }
        }

        // memory step on both blocks
        for l in 0..k {
            let dh = d_hat.col(l).to_vec();
            let dl = d.col_mut(l);
            for i in 0..dr {
                dl[i] += gamma * (dh[i] - dl[i]);
            }
        }
        for j in 0..ns {
            let sh = s_hat.col(j).to_vec();
            let sj = s.col_mut(j);
            for l in 0..k {
                sj[l] += gamma * (sh[l] - sj[l]);
            }
        }
        gamma *= 1.0 - opts.theta * gamma;

        trace.push(crate::metrics::TracePoint {
            iter: iters,
            wall_s: timer.elapsed_s(),
            sim_s: timer.elapsed_s(),
            obj,
            rel_err: f64::NAN,
            merit: step,
            active: k + ns,
            flops: 0.0,
        });
        if step < opts.tol {
            converged = true;
            break;
        }
    }

    DictReport { d, s, objective: obj, iters, trace, converged }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_correct() {
        let a = DenseMatrix::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DenseMatrix::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let mut out = DenseMatrix::zeros(2, 2);
        matmul_into(&a, &b, &mut out);
        // [[1+3, 2+3], [4+6, 5+6]]
        assert_eq!(out.get(0, 0), 4.0);
        assert_eq!(out.get(0, 1), 5.0);
        assert_eq!(out.get(1, 0), 10.0);
        assert_eq!(out.get(1, 1), 11.0);
    }

    #[test]
    fn instance_is_consistent() {
        let inst = dictionary_instance(8, 5, 20, 0.4, 0.0, 3);
        // noiseless: Y = D S exactly
        let mut y = DenseMatrix::zeros(8, 20);
        matmul_into(&inst.d_true, &inst.s_true, &mut y);
        for j in 0..20 {
            assert!(vector::dist2(y.col(j), inst.y.col(j)) < 1e-12);
        }
        // dictionary columns are unit norm
        for l in 0..5 {
            assert!((vector::nrm2(inst.d_true.col(l)) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn objective_decreases_and_fits() {
        let inst = dictionary_instance(10, 6, 30, 0.3, 0.01, 7);
        let r = solve_dictionary(&inst, &DictOptions { max_iters: 800, ..Default::default() });
        let objs: Vec<f64> = r.trace.points.iter().map(|p| p.obj).collect();
        assert!(objs.last().unwrap() < &(objs[0] * 0.2), "{} -> {}", objs[0], objs.last().unwrap());
        // dictionary columns feasible
        for l in 0..6 {
            assert!(vector::nrm2(r.d.col(l)).powi(2) <= inst.alpha + 1e-9);
        }
        // codes are sparse
        let nnz = vector::nnz(r.s.data(), 1e-6);
        assert!(nnz < r.s.data().len(), "codes not sparse at all");
    }

    #[test]
    fn near_monotone_objective() {
        let inst = dictionary_instance(8, 4, 16, 0.4, 0.0, 11);
        let r = solve_dictionary(&inst, &DictOptions::default());
        let objs: Vec<f64> = r.trace.points.iter().map(|p| p.obj).collect();
        let mut increases = 0;
        for w in objs.windows(2) {
            if w[1] > w[0] * (1.0 + 1e-6) {
                increases += 1;
            }
        }
        // diminishing-γ Jacobi on a nonconvex biconvex problem: allow a few
        // transient bumps but not systematic divergence
        assert!(increases * 10 <= objs.len(), "{increases} increases in {} iters", objs.len());
    }
}
