//! Dictionary learning for sparse representation (paper §II, sixth bullet;
//! §IV Example #4):
//!
//! ```text
//! min  ‖Y − D S‖²_F + c‖S‖₁    s.t.  ‖D e_i‖² ≤ α_i  ∀i
//! ```
//!
//! with dictionary `D ∈ R^{d×k}` and codes `S ∈ R^{k×N}`. `F` is *not
//! jointly convex* in `(D, S)` — the two-matrix-block nonconvex showcase of
//! the framework. Following Example #4 we use the **linearized**
//! approximants `P_1/P_2` (gradient at the current pair), which give
//! closed-form best responses:
//!
//! * D-block: gradient step + per-column ball projection
//!   `D̂ = Π_α( D − ∇_D F/(L_D + τ) )`;
//! * S-block: gradient step + soft threshold
//!   `Ŝ = ST( S − ∇_S F/(L_S + τ), c/(L_S + τ) )`.
//!
//! This is a standalone alternating-FLEXA driver (two giant blocks with
//! inner structure rather than the scalar-block `Problem` trait: the
//! framework's "degree of parallelism" here lives *inside* each matrix
//! block, matching the paper's description).

use super::{Problem, ProblemShard};
use crate::linalg::{vector, BlockPartition, DenseMatrix, Matrix};
use crate::metrics::Trace;
use crate::rng::Xoshiro256pp;
use crate::util::Timer;

/// A dictionary-learning instance: observations `Y ≈ D* S*`.
#[derive(Clone, Debug)]
pub struct DictionaryInstance {
    /// observed data Y (m×q)
    pub y: DenseMatrix,
    /// ℓ1 weight on the codes
    pub c: f64,
    /// column-norm bounds α_i (uniform here)
    pub alpha: f64,
    /// ground-truth dictionary D (m×r)
    pub d_true: DenseMatrix,
    /// ground-truth sparse codes S (r×q)
    pub s_true: DenseMatrix,
}

/// Generate observations from a random unit-norm dictionary and sparse codes.
pub fn dictionary_instance(
    d_rows: usize,
    k_atoms: usize,
    n_samples: usize,
    code_sparsity: f64,
    noise: f64,
    seed: u64,
) -> DictionaryInstance {
    let mut rng = Xoshiro256pp::seed_from_u64(seed);
    let mut d = DenseMatrix::zeros(d_rows, k_atoms);
    for j in 0..k_atoms {
        let col = d.col_mut(j);
        rng.fill_normal(col);
        let nrm = vector::nrm2(col);
        vector::scale(1.0 / nrm, col);
    }
    let mut s = DenseMatrix::zeros(k_atoms, n_samples);
    let nnz_per_col = ((k_atoms as f64 * code_sparsity).ceil() as usize).max(1);
    for j in 0..n_samples {
        for &i in &rng.choose_k(k_atoms, nnz_per_col) {
            s.set(i, j, rng.next_normal());
        }
    }
    // Y = D S + noise
    let mut y = DenseMatrix::zeros(d_rows, n_samples);
    matmul_into(&d, &s, &mut y);
    for j in 0..n_samples {
        for v in y.col_mut(j) {
            *v += noise * rng.next_normal();
        }
    }
    DictionaryInstance { y, c: 0.1, alpha: 1.0, d_true: d, s_true: s }
}

/// `out = A·B` (column-major, small matrices — the substrate for this
/// problem only; the big solvers never need dense matmul).
pub fn matmul_into(a: &DenseMatrix, b: &DenseMatrix, out: &mut DenseMatrix) {
    assert_eq!(a.ncols(), b.nrows());
    assert_eq!(out.nrows(), a.nrows());
    assert_eq!(out.ncols(), b.ncols());
    for j in 0..b.ncols() {
        let out_col = out.col_mut(j);
        out_col.fill(0.0);
        for l in 0..a.ncols() {
            let blj = b.get(l, j);
            if blj != 0.0 {
                vector::axpy(blj, a.col(l), out_col);
            }
        }
    }
}

/// Options for the alternating FLEXA dictionary solver.
#[derive(Clone, Copy, Debug)]
pub struct DictOptions {
    /// outer-iteration budget
    pub max_iters: usize,
    /// objective-decrease stopping tolerance
    pub tol: f64,
    /// initial step size γ0
    pub gamma0: f64,
    /// step-size decay θ of rule (6)
    pub theta: f64,
    /// proximal weight τ
    pub tau: f64,
}

impl Default for DictOptions {
    fn default() -> Self {
        Self { max_iters: 500, tol: 1e-5, gamma0: 0.9, theta: 1e-4, tau: 1e-3 }
    }
}

/// Result of a dictionary-learning run.
pub struct DictReport {
    /// learned dictionary
    pub d: DenseMatrix,
    /// learned sparse codes
    pub s: DenseMatrix,
    /// final objective value
    pub objective: f64,
    /// outer iterations executed
    pub iters: usize,
    /// objective trace
    pub trace: Trace,
    /// whether the objective-decrease tolerance was reached
    pub converged: bool,
}

/// Alternating FLEXA (Example #4): both matrix blocks take linearized best
/// responses simultaneously (Jacobi across the two blocks), combined with
/// the diminishing-γ memory step of Algorithm 1.
pub fn solve_dictionary(inst: &DictionaryInstance, opts: &DictOptions) -> DictReport {
    let (dr, k) = (inst.y.nrows(), inst.d_true.ncols());
    let ns = inst.y.ncols();
    let mut rng = Xoshiro256pp::seed_from_u64(0xD1C7);

    // init: random unit dictionary, zero codes
    let mut d = DenseMatrix::zeros(dr, k);
    for j in 0..k {
        let col = d.col_mut(j);
        rng.fill_normal(col);
        let nrm = vector::nrm2(col);
        vector::scale(1.0 / nrm, col);
    }
    let mut s = DenseMatrix::zeros(k, ns);

    // workspaces
    let mut resid = DenseMatrix::zeros(dr, ns); // DS − Y
    let mut gd = DenseMatrix::zeros(dr, k); // ∇_D F = 2 R Sᵀ
    let mut gs = DenseMatrix::zeros(k, ns); // ∇_S F = 2 Dᵀ R
    let mut d_hat = DenseMatrix::zeros(dr, k);
    let mut s_hat = DenseMatrix::zeros(k, ns);

    let mut gamma = opts.gamma0;
    let timer = Timer::start();
    let mut trace = Trace::new("dict-FLEXA");
    let mut iters = 0;
    let mut converged = false;
    let mut obj = f64::INFINITY;

    for kiter in 0..opts.max_iters {
        iters = kiter + 1;
        // residual R = DS − Y and objective
        matmul_into(&d, &s, &mut resid);
        for j in 0..ns {
            for (r, yv) in resid.col_mut(j).iter_mut().zip(inst.y.col(j)) {
                *r -= yv;
            }
        }
        obj = resid.fro_norm().powi(2) + inst.c * vector::nrm1(s.data());

        // block Lipschitz constants (spectral upper bounds via traces)
        let l_d = 2.0 * s.fro_norm().powi(2) + opts.tau;
        let l_s = 2.0 * d.fro_norm().powi(2) + opts.tau;

        // ∇_D F = 2 R Sᵀ  (column l of gd = 2 Σ_j R_col_j · S_{l,j})
        for l in 0..k {
            let col = gd.col_mut(l);
            col.fill(0.0);
            for j in 0..ns {
                let slj = s.get(l, j);
                if slj != 0.0 {
                    vector::axpy(2.0 * slj, resid.col(j), col);
                }
            }
        }
        // ∇_S F = 2 Dᵀ R
        for j in 0..ns {
            for l in 0..k {
                gs.set(l, j, 2.0 * vector::dot(d.col(l), resid.col(j)));
            }
        }

        // best responses (linearized + prox / projection)
        for l in 0..k {
            let dl = d.col(l);
            let gl = gd.col(l);
            let hat = d_hat.col_mut(l);
            for i in 0..dr {
                hat[i] = dl[i] - gl[i] / l_d;
            }
            // project onto the α-ball
            let nrm = vector::nrm2(hat);
            if nrm * nrm > inst.alpha {
                vector::scale(inst.alpha.sqrt() / nrm, hat);
            }
        }
        let thr = inst.c / l_s;
        let mut step = 0.0f64;
        for j in 0..ns {
            for l in 0..k {
                let cur = s.get(l, j);
                let z = vector::soft_threshold(cur - gs.get(l, j) / l_s, thr);
                s_hat.set(l, j, z);
                step = step.max((z - cur).abs());
            }
        }
        for l in 0..k {
            for i in 0..dr {
                step = step.max((d_hat.get(i, l) - d.get(i, l)).abs());
            }
        }

        // memory step on both blocks
        for l in 0..k {
            let dh = d_hat.col(l).to_vec();
            let dl = d.col_mut(l);
            for i in 0..dr {
                dl[i] += gamma * (dh[i] - dl[i]);
            }
        }
        for j in 0..ns {
            let sh = s_hat.col(j).to_vec();
            let sj = s.col_mut(j);
            for l in 0..k {
                sj[l] += gamma * (sh[l] - sj[l]);
            }
        }
        gamma *= 1.0 - opts.theta * gamma;

        trace.push(crate::metrics::TracePoint {
            iter: iters,
            wall_s: timer.elapsed_s(),
            sim_s: timer.elapsed_s(),
            obj,
            rel_err: f64::NAN,
            merit: step,
            active: k + ns,
            flops: 0.0,
        });
        if step < opts.tol {
            converged = true;
            break;
        }
    }

    DictReport { d, s, objective: obj, iters, trace, converged }
}

/// The **sparse-coding stage** of dictionary learning with the dictionary
/// held fixed — the `kind = "dictionary"` problem of the config/CLI
/// surface and the engine's sixth family:
///
/// ```text
/// min_S  ‖Y − D S‖²_F + c‖S‖₁
/// ```
///
/// With `D` fixed this is a multi-right-hand-side LASSO over `x =
/// vec(S) ∈ R^{k·q}` whose effective data matrix is the block-diagonal
/// `I_q ⊗ D`: the scalar block `i = j·k + l` (sample `j`, atom `l`)
/// touches only the residual rows `d·j .. d·(j+1)` through column `D_l`.
/// The maintained auxiliary vector is the flattened residual `vec(DS −
/// Y)`, so the best response is the exact scalar subproblem of the LASSO
/// family — the same inner loops, byte for byte, which is what makes the
/// owner-computes shard view below bitwise-identical to the full-matrix
/// path.
///
/// This is the inner subproblem the alternating driver
/// [`solve_dictionary`] solves for its S-block each outer iteration; as a
/// standalone `Problem` it exposes that stage to every engine solver and
/// to `--backend sharded` (codes/samples shard; the small dictionary
/// factor is replicated per worker, as in a real distributed dictionary
/// learner — the big `Y`/`S` axes are never replicated).
pub struct DictionaryCodesProblem {
    /// Fixed dictionary `D` (d×k).
    d: DenseMatrix,
    /// Flattened observations `vec(Y)` (column-major, length d·q).
    y: Vec<f64>,
    /// ℓ1 weight on the codes.
    c: f64,
    /// Atom count k (rows of S).
    k: usize,
    /// Sample count q (columns of S and Y).
    q: usize,
    /// Squared atom norms `‖D_l‖²` (best-response curvatures).
    col_sq: Vec<f64>,
    /// Scalar blocks over `vec(S)`.
    blocks: BlockPartition,
    /// Upper bound on `λmax(2 (I⊗D)ᵀ(I⊗D)) = λmax(2 DᵀD)`.
    lipschitz: f64,
}

impl DictionaryCodesProblem {
    /// Build from a fixed dictionary `d` (d×k) and observations `y`
    /// (d×q); `c` is the ℓ1 weight on the codes.
    pub fn new(d: DenseMatrix, y: &DenseMatrix, c: f64) -> Self {
        assert_eq!(d.nrows(), y.nrows(), "dictionary/observation row mismatch");
        assert!(c > 0.0);
        let (k, q) = (d.ncols(), y.ncols());
        let col_sq = d.col_sq_norms();
        let lipschitz = Matrix::Dense(d.clone()).lipschitz_2ata(30, 0xD1C7);
        Self {
            y: y.data().to_vec(),
            c,
            k,
            q,
            col_sq,
            blocks: BlockPartition::scalar(k * q),
            lipschitz,
            d,
        }
    }

    /// Build the sparse-coding stage of a generated
    /// [`DictionaryInstance`], holding the dictionary at the generator's
    /// ground truth (the codes then have a meaningful sparse solution).
    pub fn from_instance(inst: &DictionaryInstance) -> Self {
        Self::new(inst.d_true.clone(), &inst.y, inst.c)
    }

    /// ℓ1 weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }

    /// Atom count k.
    pub fn atoms(&self) -> usize {
        self.k
    }

    /// Sample count q.
    pub fn samples(&self) -> usize {
        self.q
    }

    /// Sample index `j` and atom index `l` of scalar block `i = j·k + l`.
    #[inline]
    fn split(&self, i: usize) -> (usize, usize) {
        (i / self.k, i % self.k)
    }

    /// Residual rows of sample `j`: `d·j .. d·(j+1)`.
    #[inline]
    fn rows_of(&self, j: usize) -> std::ops::Range<usize> {
        let d = self.d.nrows();
        d * j..d * (j + 1)
    }
}

/// Shared scalar-code best response: the exact LASSO subproblem of block
/// `i = j·k + l` against atom column `D_l` and the sample-`j` residual
/// rows. One body serves [`DictionaryCodesProblem`] and its shard, so
/// the two paths can never drift numerically.
fn code_best_response(
    d: &DenseMatrix,
    k: usize,
    col_sq: &[f64],
    c: f64,
    i: usize,
    x_i: f64,
    aux: &[f64],
    tau: f64,
    out: &mut [f64],
) -> f64 {
    let (j, l) = (i / k, i % k);
    let dr = d.nrows();
    let g = 2.0 * vector::dot(d.col(l), &aux[dr * j..dr * (j + 1)]);
    let denom = 2.0 * col_sq[l] + tau;
    debug_assert!(denom > 0.0, "degenerate atom {l} with tau = {tau}");
    let z = vector::soft_threshold(x_i - g / denom, c / denom);
    out[0] = z;
    (z - x_i).abs()
}

/// Shared delta propagation: `aux_j += delta · D_l` for block `i = j·k + l`.
fn code_apply_delta(d: &DenseMatrix, k: usize, i: usize, delta: f64, aux: &mut [f64]) {
    if delta != 0.0 {
        let (j, l) = (i / k, i % k);
        let dr = d.nrows();
        vector::axpy(delta, d.col(l), &mut aux[dr * j..dr * (j + 1)]);
    }
}

impl Problem for DictionaryCodesProblem {
    fn n(&self) -> usize {
        self.k * self.q
    }

    fn aux_len(&self) -> usize {
        self.d.nrows() * self.q
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        // per sample: aux_j = D s_j − y_j (column-major segments)
        for j in 0..self.q {
            let rows = self.rows_of(j);
            let seg = &mut aux[rows.clone()];
            seg.fill(0.0);
            for l in 0..self.k {
                let slj = x[j * self.k + l];
                if slj != 0.0 {
                    vector::axpy(slj, self.d.col(l), seg);
                }
            }
            for (r, yv) in seg.iter_mut().zip(&self.y[rows]) {
                *r -= yv;
            }
        }
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        vector::nrm2_sq(aux)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        self.c * vector::nrm1(x)
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        let (j, l) = self.split(i);
        out[0] = 2.0 * vector::dot(self.d.col(l), &aux[self.rows_of(j)]);
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        code_best_response(&self.d, self.k, &self.col_sq, self.c, i, x[i], aux, tau, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        code_apply_delta(&self.d, self.k, i, delta[0], aux);
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        if delta[0] == 0.0 {
            return;
        }
        let (j, l) = self.split(i);
        let span = self.rows_of(j);
        let lo = span.start.max(rows.start);
        let hi = span.end.min(rows.end);
        if lo >= hi {
            return;
        }
        let col = self.d.col(l);
        for t in lo..hi {
            aux_rows[t - rows.start] += delta[0] * col[t - span.start];
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        vector::nrm2_sq(aux_rows)
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        // ∇F = 2 (I⊗D)ᵀ aux: per sample, 2 Dᵀ aux_j
        for j in 0..self.q {
            let seg = &aux[self.rows_of(j)];
            for l in 0..self.k {
                out[j * self.k + l] = 2.0 * vector::dot(self.d.col(l), seg);
            }
        }
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        vector::soft_threshold_vec(v, step * self.c, out);
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        super::l1_merit_inf(&g, x, self.c, None)
    }

    fn tau_init(&self) -> f64 {
        // tr((I⊗D)ᵀ(I⊗D))/2n = q·tr(DᵀD)/(2·k·q) = Σ_l ‖D_l‖²/(2k)
        self.col_sq.iter().sum::<f64>() / (2.0 * self.k as f64)
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // scalar blocks: ∂²_i F = 2‖D_l‖²
        2.0 * self.col_sq[i % self.k]
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // owner-computes on the codes/samples axis: the shard's effective
        // columns are built from the small dictionary factor alone, so D
        // is replicated per worker while the big Y/S axes stay sharded
        Some(Box::new(DictCodesShard {
            d: self.d.clone(),
            c: self.c,
            k: self.k,
            col_sq: self.col_sq.clone(),
            blocks,
        }))
    }

    fn flops_best_response(&self, _i: usize) -> f64 {
        // one atom-column dot + soft-threshold
        2.0 * self.d.nrows() as f64 + 6.0
    }

    fn flops_aux_update(&self, _i: usize) -> f64 {
        2.0 * self.d.nrows() as f64
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * (self.d.nrows() * self.k * self.q) as f64 + self.n() as f64
    }

    fn flops_obj(&self) -> f64 {
        2.0 * (self.aux_len() + self.n()) as f64
    }
}

/// Column shard of a [`DictionaryCodesProblem`]: the owned scalar code
/// blocks plus a replicated copy of the **small** dictionary factor `D`
/// (d×k), from which every owned effective column of `I_q ⊗ D` is read.
/// No worker holds the full observations `Y` or codes outside its range;
/// both paths run the single [`code_best_response`]/[`code_apply_delta`]
/// kernels, so results are bitwise equal by construction.
struct DictCodesShard {
    /// Replicated dictionary factor `D` (d×k).
    d: DenseMatrix,
    /// ℓ1 weight `c`.
    c: f64,
    /// Atom count k (block `i = j·k + l`).
    k: usize,
    /// Squared atom norms `‖D_l‖²`.
    col_sq: Vec<f64>,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for DictCodesShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        code_best_response(&self.d, self.k, &self.col_sq, self.c, i, x[i], aux, tau, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        code_apply_delta(&self.d, self.k, i, delta[0], aux);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_correct() {
        let a = DenseMatrix::from_row_major(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DenseMatrix::from_row_major(3, 2, &[1.0, 0.0, 0.0, 1.0, 1.0, 1.0]);
        let mut out = DenseMatrix::zeros(2, 2);
        matmul_into(&a, &b, &mut out);
        // [[1+3, 2+3], [4+6, 5+6]]
        assert_eq!(out.get(0, 0), 4.0);
        assert_eq!(out.get(0, 1), 5.0);
        assert_eq!(out.get(1, 0), 10.0);
        assert_eq!(out.get(1, 1), 11.0);
    }

    #[test]
    fn instance_is_consistent() {
        let inst = dictionary_instance(8, 5, 20, 0.4, 0.0, 3);
        // noiseless: Y = D S exactly
        let mut y = DenseMatrix::zeros(8, 20);
        matmul_into(&inst.d_true, &inst.s_true, &mut y);
        for j in 0..20 {
            assert!(vector::dist2(y.col(j), inst.y.col(j)) < 1e-12);
        }
        // dictionary columns are unit norm
        for l in 0..5 {
            assert!((vector::nrm2(inst.d_true.col(l)) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn objective_decreases_and_fits() {
        let inst = dictionary_instance(10, 6, 30, 0.3, 0.01, 7);
        let r = solve_dictionary(&inst, &DictOptions { max_iters: 800, ..Default::default() });
        let objs: Vec<f64> = r.trace.points.iter().map(|p| p.obj).collect();
        assert!(objs.last().unwrap() < &(objs[0] * 0.2), "{} -> {}", objs[0], objs.last().unwrap());
        // dictionary columns feasible
        for l in 0..6 {
            assert!(vector::nrm2(r.d.col(l)).powi(2) <= inst.alpha + 1e-9);
        }
        // codes are sparse
        let nnz = vector::nnz(r.s.data(), 1e-6);
        assert!(nnz < r.s.data().len(), "codes not sparse at all");
    }

    fn codes_problem() -> DictionaryCodesProblem {
        let inst = dictionary_instance(10, 6, 12, 0.3, 0.01, 21);
        DictionaryCodesProblem::from_instance(&inst)
    }

    #[test]
    fn codes_problem_shapes_and_aux() {
        let p = codes_problem();
        assert_eq!(p.n(), 6 * 12);
        assert_eq!(p.aux_len(), 10 * 12);
        assert_eq!(p.blocks().n_blocks(), p.n());
        // at S = 0 the residual is −Y, so F(0) = ‖Y‖²_F
        let x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let yf: f64 = p.y.iter().map(|v| v * v).sum();
        assert!((p.f_val(&x, &aux) - yf).abs() < 1e-10);
        assert_eq!(p.g_val(&x), 0.0);
    }

    #[test]
    fn codes_grad_matches_finite_differences() {
        let p = codes_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(3);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut g = vec![0.0; p.n()];
        p.grad_full(&x, &aux, &mut g);
        let h = 1e-6;
        for i in [0, 7, p.n() - 1] {
            let mut gi = [0.0];
            p.block_grad(i, &x, &aux, &mut gi);
            assert!((gi[0] - g[i]).abs() < 1e-10, "block grad vs full at {i}");
            let mut xp = x.clone();
            xp[i] += h;
            let mut ap = vec![0.0; p.aux_len()];
            p.init_aux(&xp, &mut ap);
            let mut xm = x.clone();
            xm[i] -= h;
            let mut am = vec![0.0; p.aux_len()];
            p.init_aux(&xm, &mut am);
            let fd = (p.f_val(&xp, &ap) - p.f_val(&xm, &am)) / (2.0 * h);
            assert!((fd - g[i]).abs() < 1e-4, "i={i}: {fd} vs {}", g[i]);
        }
    }

    #[test]
    fn codes_incremental_aux_matches_recompute() {
        let p = codes_problem();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let mut rng = Xoshiro256pp::seed_from_u64(8);
        for _ in 0..80 {
            let i = rng.next_usize(p.n());
            let d = rng.next_normal() * 0.2;
            x[i] += d;
            p.apply_block_delta(i, &[d], &mut aux);
        }
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-9);
    }

    #[test]
    fn codes_ranged_delta_matches_full_delta() {
        let p = codes_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(14);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        for i in [0, 13, p.n() - 1] {
            let mut full = aux.clone();
            p.apply_block_delta(i, &[0.4], &mut full);
            // chunked: apply to two halves independently
            let mut chunked = aux.clone();
            let mid = p.aux_len() / 2;
            let (a, b) = chunked.split_at_mut(mid);
            p.apply_block_delta_rows(i, &[0.4], a, 0..mid);
            p.apply_block_delta_rows(i, &[0.4], b, mid..p.aux_len());
            assert_eq!(full, chunked, "block {i}");
        }
    }

    #[test]
    fn codes_best_response_solves_scalar_subproblem() {
        let p = codes_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(5);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.5).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = 0.7;
        let q = |i: usize, u: f64| -> f64 {
            let mut xt = x.clone();
            xt[i] = u;
            let mut at = vec![0.0; p.aux_len()];
            p.init_aux(&xt, &mut at);
            p.f_val(&xt, &at) + tau / 2.0 * (u - x[i]).powi(2) + p.c() * u.abs()
        };
        for i in [0, 11, 29] {
            let mut z = [0.0];
            let e = p.best_response(i, &x, &aux, tau, &mut z);
            assert!((e - (z[0] - x[i]).abs()).abs() < 1e-12);
            let qz = q(i, z[0]);
            for du in [-0.01, 0.01, -0.1, 0.1] {
                assert!(q(i, z[0] + du) >= qz - 1e-9, "i={i} du={du}");
            }
        }
    }

    #[test]
    fn codes_column_shard_matches_full_problem_bitwise() {
        let p = codes_problem();
        let mut rng = Xoshiro256pp::seed_from_u64(9);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.4).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let lo = p.n() / 3;
        let hi = 2 * p.n() / 3;
        let shard = p.column_shard(lo..hi).expect("dictionary codes shard");
        assert_eq!(shard.block_range(), lo..hi);
        let (mut zf, mut zs) = ([0.0], [0.0]);
        for i in lo..hi {
            let ef = p.best_response(i, &x, &aux, 0.7, &mut zf);
            let es = shard.best_response(i, &x, &aux, 0.7, &mut zs);
            assert_eq!(ef, es, "E_{i}");
            assert_eq!(zf[0], zs[0], "zhat_{i}");
            let mut af = aux.clone();
            let mut as_ = aux.clone();
            p.apply_block_delta(i, &[0.3], &mut af);
            shard.apply_block_delta(i, &[0.3], &mut as_);
            assert_eq!(af, as_, "delta block {i}");
        }
    }

    #[test]
    fn near_monotone_objective() {
        let inst = dictionary_instance(8, 4, 16, 0.4, 0.0, 11);
        let r = solve_dictionary(&inst, &DictOptions::default());
        let objs: Vec<f64> = r.trace.points.iter().map(|p| p.obj).collect();
        let mut increases = 0;
        for w in objs.windows(2) {
            if w[1] > w[0] * (1.0 + 1e-6) {
                increases += 1;
            }
        }
        // diminishing-γ Jacobi on a nonconvex biconvex problem: allow a few
        // transient bumps but not systematic divergence
        assert!(increases * 10 <= objs.len(), "{increases} increases in {} iters", objs.len());
    }
}
