//! Group LASSO: `min ‖Ax − b‖² + c Σ_I ‖x_I‖₂` (paper §II), blocks of size
//! `> 1`. Exercises the framework's non-scalar block path.
//!
//! Best response uses the paper's *linearized* approximant
//! `P_I(x_I; x^k) = F(x^k) + ∇_I F(x^k)ᵀ(x_I − x_I^k)` with a scaled
//! identity proximal term `(L_I + τ)/2 ‖x_I − x_I^k‖²`, where
//! `L_I = 2‖A_I‖_F²` upper-bounds the block curvature `λmax(2A_IᵀA_I)`.
//! That makes the subproblem a block soft-threshold in closed form while
//! still satisfying P1–P3 (§III).

use super::{Problem, ProblemShard};
use crate::datagen::LassoInstance;
use crate::linalg::{vector, BlockPartition, Matrix};

/// Group-LASSO problem with maintained residual.
pub struct GroupLassoProblem {
    a: Matrix,
    b: Vec<f64>,
    c: f64,
    blocks: BlockPartition,
    /// per-block curvature bound `L_I = 2 Σ_{j∈I} ‖A_j‖²`
    block_lip: Vec<f64>,
    lipschitz: f64,
}

impl GroupLassoProblem {
    /// Build from raw data over an explicit block partition.
    pub fn new(a: Matrix, b: Vec<f64>, c: f64, blocks: BlockPartition) -> Self {
        assert_eq!(a.nrows(), b.len());
        assert_eq!(blocks.dim(), a.ncols());
        let col_sq = a.col_sq_norms();
        let block_lip = (0..blocks.n_blocks())
            .map(|i| 2.0 * blocks.range(i).map(|j| col_sq[j]).sum::<f64>())
            .collect();
        let lipschitz = a.lipschitz_2ata(30, 0xF00D);
        Self { a, b, c, blocks, block_lip, lipschitz }
    }

    /// Build from a LASSO instance with uniform blocks of `block_size`.
    /// (Note: the generator's `x*`/`V*` are optimal for the ℓ1 problem, not
    /// the group problem, so no `v_star` is claimed here.)
    pub fn from_instance(inst: LassoInstance, block_size: usize) -> Self {
        let n = inst.a.ncols();
        Self::new(inst.a, inst.b, inst.c, BlockPartition::uniform(n, block_size))
    }

    /// Group-norm weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }
}

/// Shared block best response: the linearized block soft-threshold of
/// block `range` with proximal denominator `denom = L_I + τ`.
/// `col_offset` translates global column indices into the caller's
/// storage (0 for the full matrix, the shard's first column otherwise),
/// so one body serves [`GroupLassoProblem`] and its shard and the two
/// paths can never drift numerically.
fn group_best_response(
    a: &Matrix,
    col_offset: usize,
    range: std::ops::Range<usize>,
    denom: f64,
    c: f64,
    x: &[f64],
    aux: &[f64],
    out: &mut [f64],
) -> f64 {
    let bsize = range.len();
    debug_assert_eq!(out.len(), bsize);
    debug_assert!(denom > 0.0);
    // v = x_I − ∇_I F / denom, then block soft-threshold with c/denom
    let mut v = vec![0.0; bsize];
    for (k, j) in range.clone().enumerate() {
        let g = 2.0 * a.col_dot(j - col_offset, aux);
        v[k] = x[range.start + k] - g / denom;
    }
    vector::block_soft_threshold(&v, c / denom, out);
    let mut e2 = 0.0;
    for (k, j) in range.enumerate() {
        let d = out[k] - x[j];
        e2 += d * d;
    }
    e2.sqrt()
}

/// Shared delta propagation: per-column axpy of the block step, with the
/// same `col_offset` translation as [`group_best_response`].
fn group_apply_delta(
    a: &Matrix,
    col_offset: usize,
    range: std::ops::Range<usize>,
    delta: &[f64],
    aux: &mut [f64],
) {
    for (k, j) in range.enumerate() {
        if delta[k] != 0.0 {
            a.col_axpy(j - col_offset, delta[k], aux);
        }
    }
}

impl Problem for GroupLassoProblem {
    fn n(&self) -> usize {
        self.a.ncols()
    }

    fn aux_len(&self) -> usize {
        self.a.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.a.matvec(x, aux);
        for (r, bi) in aux.iter_mut().zip(&self.b) {
            *r -= bi;
        }
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        vector::nrm2_sq(aux)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        (0..self.blocks.n_blocks())
            .map(|i| self.c * vector::nrm2(&x[self.blocks.range(i)]))
            .sum()
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        for (k, j) in self.blocks.range(i).enumerate() {
            out[k] = 2.0 * self.a.col_dot(j, aux);
        }
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let denom = self.block_lip[i] + tau;
        group_best_response(&self.a, 0, self.blocks.range(i), denom, self.c, x, aux, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        group_apply_delta(&self.a, 0, self.blocks.range(i), delta, aux);
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        for (k, j) in self.blocks.range(i).enumerate() {
            if delta[k] != 0.0 {
                self.a.col_axpy_range(j, delta[k], aux_rows, rows.clone());
            }
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        vector::nrm2_sq(aux_rows)
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.a.matvec_t(aux, out);
        vector::scale(2.0, out);
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        for i in 0..self.blocks.n_blocks() {
            let r = self.blocks.range(i);
            let (vi, oi) = (&v[r.clone()], &mut out[r]);
            vector::block_soft_threshold(vi, step * self.c, oi);
        }
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        // natural-residual merit for the group norm: per block,
        // ‖x_I − prox_{c‖·‖}(x_I − ∇_I F)‖∞ over blocks
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        let mut worst = 0.0f64;
        for i in 0..self.blocks.n_blocks() {
            let r = self.blocks.range(i);
            let v: Vec<f64> = r.clone().map(|j| x[j] - g[j]).collect();
            let mut p = vec![0.0; v.len()];
            vector::block_soft_threshold(&v, self.c, &mut p);
            let d: f64 = r
                .clone()
                .enumerate()
                .map(|(k, j)| (x[j] - p[k]).powi(2))
                .sum::<f64>()
                .sqrt();
            worst = worst.max(d);
        }
        worst
    }

    fn tau_init(&self) -> f64 {
        self.a.gram_trace() / (2.0 * self.n() as f64)
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // precomputed block curvature bound L_I = 2 Σ_{j∈I} ‖A_j‖²
        self.block_lip[i]
    }

    fn column_shard(&self, blocks: std::ops::Range<usize>) -> Option<Box<dyn ProblemShard>> {
        // blocks are contiguous column groups, so a contiguous block range
        // maps to one contiguous column range
        let nb = self.blocks.n_blocks();
        let cols = if blocks.is_empty() {
            let at = if blocks.start < nb {
                self.blocks.range(blocks.start).start
            } else {
                self.blocks.dim()
            };
            at..at
        } else {
            self.blocks.range(blocks.start).start..self.blocks.range(blocks.end - 1).end
        };
        Some(Box::new(GroupLassoShard {
            a: self.a.columns_range(cols.clone()),
            c: self.c,
            block_lip: self.block_lip[blocks.clone()].to_vec(),
            col_start: cols.start,
            partition: self.blocks.clone(),
            blocks,
        }))
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        let cols: f64 = self.blocks.range(i).map(|j| self.a.col_nnz(j) as f64).sum();
        2.0 * cols + 8.0 * self.blocks.size(i) as f64
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.blocks.range(i).map(|j| self.a.col_nnz(j) as f64).sum::<f64>()
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.a.nnz() as f64 + self.n() as f64
    }

    fn flops_obj(&self) -> f64 {
        2.0 * (self.aux_len() + self.n()) as f64
    }
}

/// Column shard of a [`GroupLassoProblem`]: copies of the owned blocks'
/// columns plus their curvature bounds `L_I` — everything the
/// owner-computes block soft-threshold touches. The global block
/// partition is replicated (offsets metadata only, like the block map of
/// a real cluster run; the data matrix itself is never replicated).
/// Both paths run the single [`group_best_response`] /
/// [`group_apply_delta`] kernels, so results are bitwise equal by
/// construction.
struct GroupLassoShard {
    /// The shard's columns `A_s` (m × |cols|).
    a: Matrix,
    /// Group-norm weight `c`.
    c: f64,
    /// Curvature bounds of the owned blocks (`block_lip[i − start]`).
    block_lip: Vec<f64>,
    /// Global column index of the shard's first column.
    col_start: usize,
    /// Replicated global block partition (offsets metadata).
    partition: BlockPartition,
    /// Owned global block range.
    blocks: std::ops::Range<usize>,
}

impl ProblemShard for GroupLassoShard {
    fn block_range(&self) -> std::ops::Range<usize> {
        self.blocks.clone()
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let denom = self.block_lip[i - self.blocks.start] + tau;
        let range = self.partition.range(i);
        group_best_response(&self.a, self.col_start, range, denom, self.c, x, aux, out)
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        group_apply_delta(&self.a, self.col_start, self.partition.range(i), delta, aux);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::nesterov_lasso;

    fn small() -> GroupLassoProblem {
        GroupLassoProblem::from_instance(nesterov_lasso(20, 24, 0.2, 1.0, 55), 4)
    }

    #[test]
    fn column_shard_matches_full_problem_bitwise() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(31);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.4).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        // a middle shard: blocks 2..5 of the 6 size-4 blocks
        let shard = p.column_shard(2..5).expect("group-lasso shards");
        assert_eq!(shard.block_range(), 2..5);
        for i in 2..5 {
            let r = p.blocks().range(i);
            let (mut zf, mut zs) = (vec![0.0; r.len()], vec![0.0; r.len()]);
            let ef = p.best_response(i, &x, &aux, 0.7, &mut zf);
            let es = shard.best_response(i, &x, &aux, 0.7, &mut zs);
            assert_eq!(ef, es, "E_{i}");
            assert_eq!(zf, zs, "zhat block {i}");
            let delta = vec![0.25; r.len()];
            let mut af = aux.clone();
            let mut as_ = aux.clone();
            p.apply_block_delta(i, &delta, &mut af);
            shard.apply_block_delta(i, &delta, &mut as_);
            assert_eq!(af, as_, "delta block {i}");
        }
    }

    #[test]
    fn empty_shard_range_is_well_formed() {
        let p = small();
        let nb = p.blocks().n_blocks();
        // ShardLayout can hand out empty ranges when shards > blocks
        let shard = p.column_shard(nb..nb).expect("empty shard");
        assert_eq!(shard.block_range(), nb..nb);
    }

    #[test]
    fn blocks_are_grouped() {
        let p = small();
        assert_eq!(p.blocks().n_blocks(), 6);
        assert_eq!(p.blocks().size(0), 4);
    }

    #[test]
    fn g_val_is_sum_of_block_norms() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        x[0] = 3.0;
        x[1] = 4.0; // block 0 norm 5
        x[4] = 1.0; // block 1 norm 1
        assert!((p.g_val(&x) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn best_response_improves_surrogate() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(12);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = 1.0;
        for i in 0..p.blocks().n_blocks() {
            let r = p.blocks().range(i);
            let mut z = vec![0.0; r.len()];
            let e = p.best_response(i, &x, &aux, tau, &mut z);
            // surrogate value at z must be ≤ at x_I (z is its minimizer)
            let mut g = vec![0.0; r.len()];
            p.block_grad(i, &x, &aux, &mut g);
            let denom = p.block_lip[i] + tau;
            let s = |u: &[f64]| -> f64 {
                let mut acc = 0.0;
                for k in 0..u.len() {
                    let d = u[k] - x[r.start + k];
                    acc += g[k] * d + 0.5 * denom * d * d;
                }
                acc + p.c() * vector::nrm2(u)
            };
            let xi: Vec<f64> = r.clone().map(|j| x[j]).collect();
            assert!(s(&z) <= s(&xi) + 1e-10, "block {i}");
            assert!(e >= 0.0);
        }
    }

    #[test]
    fn incremental_aux_matches() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let delta = [0.3, -0.2, 0.0, 0.15];
        for (k, j) in p.blocks().range(2).enumerate() {
            x[j] += delta[k];
        }
        p.apply_block_delta(2, &delta, &mut aux);
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-10);
    }

    #[test]
    fn merit_decreases_under_gs_sweeps() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let m0 = p.merit(&x, &aux);
        // the linearized approximant with the Frobenius curvature bound is
        // conservative ⇒ geometric but slow; use a light τ and more sweeps
        let tau = 0.1 * p.tau_init();
        for _ in 0..2000 {
            for i in 0..p.blocks().n_blocks() {
                let r = p.blocks().range(i);
                let mut z = vec![0.0; r.len()];
                p.best_response(i, &x, &aux, tau, &mut z);
                let delta: Vec<f64> =
                    r.clone().enumerate().map(|(k, j)| z[k] - x[j]).collect();
                for (k, j) in r.clone().enumerate() {
                    x[j] = z[k];
                }
                p.apply_block_delta(i, &delta, &mut aux);
            }
        }
        let m1 = p.merit(&x, &aux);
        assert!(m1 < m0 * 0.02, "merit {m0} -> {m1}");
    }
}
