//! Group LASSO: `min ‖Ax − b‖² + c Σ_I ‖x_I‖₂` (paper §II), blocks of size
//! `> 1`. Exercises the framework's non-scalar block path.
//!
//! Best response uses the paper's *linearized* approximant
//! `P_I(x_I; x^k) = F(x^k) + ∇_I F(x^k)ᵀ(x_I − x_I^k)` with a scaled
//! identity proximal term `(L_I + τ)/2 ‖x_I − x_I^k‖²`, where
//! `L_I = 2‖A_I‖_F²` upper-bounds the block curvature `λmax(2A_IᵀA_I)`.
//! That makes the subproblem a block soft-threshold in closed form while
//! still satisfying P1–P3 (§III).

use super::Problem;
use crate::datagen::LassoInstance;
use crate::linalg::{vector, BlockPartition, Matrix};

/// Group-LASSO problem with maintained residual.
pub struct GroupLassoProblem {
    a: Matrix,
    b: Vec<f64>,
    c: f64,
    blocks: BlockPartition,
    /// per-block curvature bound `L_I = 2 Σ_{j∈I} ‖A_j‖²`
    block_lip: Vec<f64>,
    lipschitz: f64,
}

impl GroupLassoProblem {
    /// Build from raw data over an explicit block partition.
    pub fn new(a: Matrix, b: Vec<f64>, c: f64, blocks: BlockPartition) -> Self {
        assert_eq!(a.nrows(), b.len());
        assert_eq!(blocks.dim(), a.ncols());
        let col_sq = a.col_sq_norms();
        let block_lip = (0..blocks.n_blocks())
            .map(|i| 2.0 * blocks.range(i).map(|j| col_sq[j]).sum::<f64>())
            .collect();
        let lipschitz = a.lipschitz_2ata(30, 0xF00D);
        Self { a, b, c, blocks, block_lip, lipschitz }
    }

    /// Build from a LASSO instance with uniform blocks of `block_size`.
    /// (Note: the generator's `x*`/`V*` are optimal for the ℓ1 problem, not
    /// the group problem, so no `v_star` is claimed here.)
    pub fn from_instance(inst: LassoInstance, block_size: usize) -> Self {
        let n = inst.a.ncols();
        Self::new(inst.a, inst.b, inst.c, BlockPartition::uniform(n, block_size))
    }

    /// Group-norm weight `c`.
    pub fn c(&self) -> f64 {
        self.c
    }
}

impl Problem for GroupLassoProblem {
    fn n(&self) -> usize {
        self.a.ncols()
    }

    fn aux_len(&self) -> usize {
        self.a.nrows()
    }

    fn blocks(&self) -> &BlockPartition {
        &self.blocks
    }

    fn init_aux(&self, x: &[f64], aux: &mut [f64]) {
        self.a.matvec(x, aux);
        for (r, bi) in aux.iter_mut().zip(&self.b) {
            *r -= bi;
        }
    }

    fn f_val(&self, _x: &[f64], aux: &[f64]) -> f64 {
        vector::nrm2_sq(aux)
    }

    fn g_val(&self, x: &[f64]) -> f64 {
        (0..self.blocks.n_blocks())
            .map(|i| self.c * vector::nrm2(&x[self.blocks.range(i)]))
            .sum()
    }

    fn block_grad(&self, i: usize, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        for (k, j) in self.blocks.range(i).enumerate() {
            out[k] = 2.0 * self.a.col_dot(j, aux);
        }
    }

    fn best_response(&self, i: usize, x: &[f64], aux: &[f64], tau: f64, out: &mut [f64]) -> f64 {
        let range = self.blocks.range(i);
        let bsize = range.len();
        debug_assert_eq!(out.len(), bsize);
        let denom = self.block_lip[i] + tau;
        debug_assert!(denom > 0.0);
        // v = x_I − ∇_I F / denom, then block soft-threshold with c/denom
        let mut v = vec![0.0; bsize];
        for (k, j) in range.clone().enumerate() {
            let g = 2.0 * self.a.col_dot(j, aux);
            v[k] = x[range.start + k] - g / denom;
        }
        vector::block_soft_threshold(&v, self.c / denom, out);
        let mut e2 = 0.0;
        for (k, j) in range.enumerate() {
            let d = out[k] - x[j];
            e2 += d * d;
        }
        e2.sqrt()
    }

    fn apply_block_delta(&self, i: usize, delta: &[f64], aux: &mut [f64]) {
        for (k, j) in self.blocks.range(i).enumerate() {
            if delta[k] != 0.0 {
                self.a.col_axpy(j, delta[k], aux);
            }
        }
    }

    fn apply_block_delta_rows(
        &self,
        i: usize,
        delta: &[f64],
        aux_rows: &mut [f64],
        rows: std::ops::Range<usize>,
    ) {
        for (k, j) in self.blocks.range(i).enumerate() {
            if delta[k] != 0.0 {
                self.a.col_axpy_range(j, delta[k], aux_rows, rows.clone());
            }
        }
    }

    fn f_val_rows(&self, _x: &[f64], aux_rows: &[f64], _rows: std::ops::Range<usize>) -> f64 {
        vector::nrm2_sq(aux_rows)
    }

    fn supports_chunked_obj(&self) -> bool {
        true
    }

    fn grad_full(&self, _x: &[f64], aux: &[f64], out: &mut [f64]) {
        self.a.matvec_t(aux, out);
        vector::scale(2.0, out);
    }

    fn prox_full(&self, v: &[f64], step: f64, out: &mut [f64]) {
        for i in 0..self.blocks.n_blocks() {
            let r = self.blocks.range(i);
            let (vi, oi) = (&v[r.clone()], &mut out[r]);
            vector::block_soft_threshold(vi, step * self.c, oi);
        }
    }

    fn merit(&self, x: &[f64], aux: &[f64]) -> f64 {
        // natural-residual merit for the group norm: per block,
        // ‖x_I − prox_{c‖·‖}(x_I − ∇_I F)‖∞ over blocks
        let mut g = vec![0.0; self.n()];
        self.grad_full(x, aux, &mut g);
        let mut worst = 0.0f64;
        for i in 0..self.blocks.n_blocks() {
            let r = self.blocks.range(i);
            let v: Vec<f64> = r.clone().map(|j| x[j] - g[j]).collect();
            let mut p = vec![0.0; v.len()];
            vector::block_soft_threshold(&v, self.c, &mut p);
            let d: f64 = r
                .clone()
                .enumerate()
                .map(|(k, j)| (x[j] - p[k]).powi(2))
                .sum::<f64>()
                .sqrt();
            worst = worst.max(d);
        }
        worst
    }

    fn tau_init(&self) -> f64 {
        self.a.gram_trace() / (2.0 * self.n() as f64)
    }

    fn lipschitz(&self) -> f64 {
        self.lipschitz
    }

    fn block_lipschitz(&self, i: usize) -> f64 {
        // precomputed block curvature bound L_I = 2 Σ_{j∈I} ‖A_j‖²
        self.block_lip[i]
    }

    fn flops_best_response(&self, i: usize) -> f64 {
        let cols: f64 = self.blocks.range(i).map(|j| self.a.col_nnz(j) as f64).sum();
        2.0 * cols + 8.0 * self.blocks.size(i) as f64
    }

    fn flops_aux_update(&self, i: usize) -> f64 {
        2.0 * self.blocks.range(i).map(|j| self.a.col_nnz(j) as f64).sum::<f64>()
    }

    fn flops_grad_full(&self) -> f64 {
        2.0 * self.a.nnz() as f64 + self.n() as f64
    }

    fn flops_obj(&self) -> f64 {
        2.0 * (self.aux_len() + self.n()) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::nesterov_lasso;

    fn small() -> GroupLassoProblem {
        GroupLassoProblem::from_instance(nesterov_lasso(20, 24, 0.2, 1.0, 55), 4)
    }

    #[test]
    fn blocks_are_grouped() {
        let p = small();
        assert_eq!(p.blocks().n_blocks(), 6);
        assert_eq!(p.blocks().size(0), 4);
    }

    #[test]
    fn g_val_is_sum_of_block_norms() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        x[0] = 3.0;
        x[1] = 4.0; // block 0 norm 5
        x[4] = 1.0; // block 1 norm 1
        assert!((p.g_val(&x) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn best_response_improves_surrogate() {
        let p = small();
        let mut rng = crate::rng::Xoshiro256pp::seed_from_u64(12);
        let x: Vec<f64> = (0..p.n()).map(|_| rng.next_normal() * 0.3).collect();
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let tau = 1.0;
        for i in 0..p.blocks().n_blocks() {
            let r = p.blocks().range(i);
            let mut z = vec![0.0; r.len()];
            let e = p.best_response(i, &x, &aux, tau, &mut z);
            // surrogate value at z must be ≤ at x_I (z is its minimizer)
            let mut g = vec![0.0; r.len()];
            p.block_grad(i, &x, &aux, &mut g);
            let denom = p.block_lip[i] + tau;
            let s = |u: &[f64]| -> f64 {
                let mut acc = 0.0;
                for k in 0..u.len() {
                    let d = u[k] - x[r.start + k];
                    acc += g[k] * d + 0.5 * denom * d * d;
                }
                acc + p.c() * vector::nrm2(u)
            };
            let xi: Vec<f64> = r.clone().map(|j| x[j]).collect();
            assert!(s(&z) <= s(&xi) + 1e-10, "block {i}");
            assert!(e >= 0.0);
        }
    }

    #[test]
    fn incremental_aux_matches() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let delta = [0.3, -0.2, 0.0, 0.15];
        for (k, j) in p.blocks().range(2).enumerate() {
            x[j] += delta[k];
        }
        p.apply_block_delta(2, &delta, &mut aux);
        let mut fresh = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut fresh);
        assert!(vector::dist2(&aux, &fresh) < 1e-10);
    }

    #[test]
    fn merit_decreases_under_gs_sweeps() {
        let p = small();
        let mut x = vec![0.0; p.n()];
        let mut aux = vec![0.0; p.aux_len()];
        p.init_aux(&x, &mut aux);
        let m0 = p.merit(&x, &aux);
        // the linearized approximant with the Frobenius curvature bound is
        // conservative ⇒ geometric but slow; use a light τ and more sweeps
        let tau = 0.1 * p.tau_init();
        for _ in 0..2000 {
            for i in 0..p.blocks().n_blocks() {
                let r = p.blocks().range(i);
                let mut z = vec![0.0; r.len()];
                p.best_response(i, &x, &aux, tau, &mut z);
                let delta: Vec<f64> =
                    r.clone().enumerate().map(|(k, j)| z[k] - x[j]).collect();
                for (k, j) in r.clone().enumerate() {
                    x[j] = z[k];
                }
                p.apply_block_delta(i, &delta, &mut aux);
            }
        }
        let m1 = p.merit(&x, &aux);
        assert!(m1 < m0 * 0.02, "merit {m0} -> {m1}");
    }
}
