//! `flexa` binary — leader entrypoint + CLI.

fn main() {
    let argv: Vec<String> = std::env::args().collect();
    match flexa::cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
