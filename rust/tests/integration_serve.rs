//! End-to-end tests of the `flexa serve` daemon: concurrent solve jobs
//! across problem families and both backends come back **bitwise
//! identical** to a direct in-process `engine` solve; warm-cache repeats
//! reuse the cached problem/pool (visible in the response's cache-hit
//! labels) without changing a single bit of the answer; tenant
//! warm-starts are opt-in; malformed requests fail clean; a `shutdown`
//! request drains the daemon.
//!
//! Everything binds an ephemeral loopback port (`port = 0`) and pins the
//! deterministic default cost model on both the daemon and the local
//! comparison solves, so `sim_s` fields are comparable. Only `wall_s` is
//! nondeterministic, and it is stripped before comparing reports.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::thread;
use std::time::Duration;

use flexa::config::{ProblemSpec, ServerSettings};
use flexa::coordinator::Backend;
use flexa::server::Server;
use flexa::simulator::CostModel;
use flexa::spec::{self, SolveSpec, SolveSpecBuilder};
use flexa::util::Json;

fn start_server() -> (SocketAddr, thread::JoinHandle<std::io::Result<()>>) {
    let settings = ServerSettings { host: "127.0.0.1".into(), port: 0 };
    let server = Server::bind_with(&settings, CostModel::default()).expect("bind ephemeral port");
    let addr = server.local_addr();
    (addr, thread::spawn(move || server.run()))
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        let writer = stream.try_clone().unwrap();
        Client { reader: BufReader::new(stream), writer }
    }

    fn request(&mut self, body: &Json) -> Json {
        let mut text = body.to_string_compact();
        text.push('\n');
        self.send_raw(&text)
    }

    fn send_raw(&mut self, line: &str) -> Json {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.flush().unwrap();
        let mut resp = String::new();
        self.reader.read_line(&mut resp).expect("response line");
        Json::parse(resp.trim()).expect("valid response JSON")
    }
}

fn shutdown(addr: SocketAddr, server: thread::JoinHandle<std::io::Result<()>>) {
    let stop = Client::connect(addr).request(&Json::obj(vec![("op", Json::str("shutdown"))]));
    assert_eq!(stop.get("stopping"), Some(&Json::Bool(true)), "{stop:?}");
    server.join().expect("server thread").expect("clean daemon exit");
}

/// Drop the only nondeterministic report field (physical wall-clock).
fn strip_wall(report: &Json) -> Json {
    let mut j = report.clone();
    if let Json::Obj(m) = &mut j {
        m.remove("wall_s");
    }
    j
}

fn solve_request(s: &SolveSpec, id: usize) -> Json {
    Json::obj(vec![
        ("op", Json::str("solve")),
        ("id", Json::Num(id as f64)),
        ("spec", s.to_json()),
        ("return_x", Json::Bool(true)),
    ])
}

/// What a direct in-process solve of the spec returns (the bitwise
/// ground truth every served response must match).
fn expected_report(s: &SolveSpec) -> Json {
    let problem = spec::build_problem(&s.problem).unwrap();
    let report = spec::execute_prepared(
        s,
        problem.as_ref(),
        spec::ExecOptions { pool: None, x0: None, model: CostModel::default() },
    )
    .expect("local solve");
    strip_wall(&report.to_json_with(true, false))
}

fn lasso() -> ProblemSpec {
    ProblemSpec::Lasso { m: 30, n: 40, sparsity: 0.1, c: 1.0, seed: 41 }
}

fn base(problem: ProblemSpec, solver: &str) -> SolveSpecBuilder {
    SolveSpec::builder()
        .problem(problem)
        .solver(solver)
        .threads(2)
        .max_iters(20)
        .tol(1e-4)
        .trace_every(20)
}

/// Four problem families × both backends, mixed solvers — the concurrent
/// workload of the equivalence test.
fn workload() -> Vec<SolveSpec> {
    let group = ProblemSpec::GroupLasso {
        m: 30,
        n: 40,
        sparsity: 0.1,
        c: 1.0,
        block_size: 4,
        seed: 42,
    };
    let logistic = ProblemSpec::Logistic { preset: "gisette".into(), scale: 0.01, seed: 43 };
    let qp = ProblemSpec::NonconvexQp {
        m: 25,
        n: 30,
        sparsity: 0.1,
        c: 10.0,
        cbar: 50.0,
        box_bound: 1.0,
        seed: 44,
    };
    let sharded = |b: SolveSpecBuilder| b.backend(Backend::Sharded).cores(2);
    vec![
        base(lasso(), "flexa").build().unwrap(),
        sharded(base(lasso(), "flexa")).build().unwrap(),
        base(group.clone(), "cdm").build().unwrap(),
        sharded(base(group, "gauss-jacobi")).build().unwrap(),
        base(logistic.clone(), "flexa").build().unwrap(),
        sharded(base(logistic, "flexa")).build().unwrap(),
        base(qp, "flexa").build().unwrap(),
    ]
}

#[test]
fn concurrent_solves_are_bitwise_identical_to_direct_engine() {
    let specs = workload();
    let expected: Vec<Json> = specs.iter().map(expected_report).collect();
    let (addr, server) = start_server();
    thread::scope(|scope| {
        for (i, (s, want)) in specs.iter().zip(&expected).enumerate() {
            scope.spawn(move || {
                let mut c = Client::connect(addr);
                let resp = c.request(&solve_request(s, i));
                assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{}: {resp:?}", s.name);
                assert_eq!(resp.get("id").and_then(Json::as_usize), Some(i));
                let report = resp.get("report").expect("report in response");
                assert_eq!(
                    &strip_wall(report),
                    want,
                    "served report diverged from direct engine solve for {} on {:?}",
                    s.name,
                    s.backend
                );
            });
        }
    });
    shutdown(addr, server);
}

#[test]
fn warm_cache_repeat_hits_and_stays_bitwise_identical() {
    let s = base(lasso(), "flexa").build().unwrap();
    let want = expected_report(&s);
    let (addr, server) = start_server();
    let mut c = Client::connect(addr);

    let cold = c.request(&solve_request(&s, 1));
    assert_eq!(cold.get("ok"), Some(&Json::Bool(true)), "{cold:?}");
    let cache = cold.get("cache").expect("cache labels");
    assert_eq!(cache.get("problem").and_then(Json::as_str), Some("miss"));
    assert_eq!(cache.get("pool").and_then(Json::as_str), Some("miss"));
    assert_eq!(strip_wall(cold.get("report").unwrap()), want);

    let warm = c.request(&solve_request(&s, 2));
    let cache = warm.get("cache").expect("cache labels");
    assert_eq!(cache.get("problem").and_then(Json::as_str), Some("hit"));
    assert_eq!(cache.get("pool").and_then(Json::as_str), Some("hit"));
    assert_eq!(
        strip_wall(warm.get("report").unwrap()),
        want,
        "warm-cache repeat drifted from the cold solve"
    );

    // a different solver on the same problem instance shares the cached
    // problem (the fingerprint keys on the problem only)
    let other = base(lasso(), "cdm").build().unwrap();
    let resp = c.request(&solve_request(&other, 3));
    assert_eq!(resp.get("ok"), Some(&Json::Bool(true)), "{resp:?}");
    let cache = resp.get("cache").expect("cache labels");
    assert_eq!(cache.get("problem").and_then(Json::as_str), Some("hit"));

    let stats = c.request(&Json::obj(vec![("op", Json::str("stats"))]));
    assert_eq!(stats.get("jobs_done").and_then(Json::as_usize), Some(3));
    let cache = stats.get("cache").expect("cache counters");
    assert_eq!(cache.get("problems").and_then(Json::as_usize), Some(1));
    assert_eq!(cache.get("problem_hits").and_then(Json::as_usize), Some(2));
    assert_eq!(cache.get("problem_misses").and_then(Json::as_usize), Some(1));

    shutdown(addr, server);
}

#[test]
fn tenant_warm_start_is_opt_in_and_per_tenant() {
    let s = base(lasso(), "flexa").build().unwrap();
    let (addr, server) = start_server();
    let mut c = Client::connect(addr);
    let req = |id: usize, tenant: &str, warm: bool| {
        solve_request(&s, id)
            .with("tenant", Json::str(tenant))
            .with("warm_start", Json::Bool(warm))
    };
    let label = |resp: &Json| {
        resp.get("cache")
            .and_then(|cj| cj.get("warm_start"))
            .and_then(Json::as_str)
            .map(str::to_string)
    };

    let first = c.request(&req(1, "alice", true));
    assert_eq!(label(&first).as_deref(), Some("miss"), "{first:?}");
    let second = c.request(&req(2, "alice", true));
    assert_eq!(label(&second).as_deref(), Some("hit"), "{second:?}");
    // another tenant never sees alice's iterate
    let third = c.request(&req(3, "bob", true));
    assert_eq!(label(&third).as_deref(), Some("miss"), "{third:?}");
    // warm_start off: the solve is cold (x0 = 0) even though an iterate
    // is stored — bitwise-identical to the first (also-cold) run
    let off = c.request(&req(4, "alice", false));
    assert_eq!(label(&off).as_deref(), Some("off"), "{off:?}");
    assert_eq!(
        strip_wall(off.get("report").unwrap()),
        strip_wall(first.get("report").unwrap()),
        "a warm_start=false solve must ignore stored iterates"
    );

    shutdown(addr, server);
}

#[test]
fn malformed_lines_fail_clean_and_the_daemon_survives() {
    let (addr, server) = start_server();
    let mut c = Client::connect(addr);

    let pong = c.request(&Json::obj(vec![("op", Json::str("ping")), ("id", Json::str("p1"))]));
    assert_eq!(pong.get("ok"), Some(&Json::Bool(true)));
    assert_eq!(pong.get("pong"), Some(&Json::Bool(true)));
    assert_eq!(pong.get("id").and_then(Json::as_str), Some("p1"));

    // not JSON at all
    let bad = c.send_raw("this is not json\n");
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    assert!(bad.get("error").is_some(), "{bad:?}");

    // valid JSON, invalid request (solve without a spec)
    let bad = c.request(&Json::obj(vec![("op", Json::str("solve")), ("id", Json::Num(9.0))]));
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    let err = bad.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(err.contains("spec"), "{bad:?}");

    // valid request shape, spec that fails validation
    let bad = c.send_raw(
        "{\"op\":\"solve\",\"spec\":{\"problem\":{\"kind\":\"lasso\",\"m\":10,\"n\":10},\
         \"solver\":\"fista\",\"backend\":\"sharded\"}}\n",
    );
    assert_eq!(bad.get("ok"), Some(&Json::Bool(false)));
    let err = bad.get("error").and_then(Json::as_str).unwrap_or_default();
    assert!(err.contains("sharded"), "{bad:?}");

    // the connection is still serviceable after every failure
    let s = base(lasso(), "flexa").build().unwrap();
    let good = c.request(&solve_request(&s, 10));
    assert_eq!(good.get("ok"), Some(&Json::Bool(true)), "{good:?}");

    shutdown(addr, server);
}
