//! End-to-end tests of the data-ingest path: committed fixtures →
//! loaders → out-of-core flexa-mmap store → file-backed [`SolveSpec`]
//! solves, pinned to the repo's bitwise backend-equivalence contract;
//! plus the malformed-fixture corpus, which must come back as typed
//! errors (never panics) that name the offending file and line.

use flexa::config::{FileKind, ProblemSpec};
use flexa::coordinator::Backend;
use flexa::io::store::MmapCscStore;
use flexa::io::{load_dataset, DataFormat};
use flexa::spec::{self, SolveSpec};

const FIXTURES: &str = "tests/fixtures/datasets";

fn fixture(name: &str) -> String {
    format!("{FIXTURES}/{name}")
}

/// Convert the committed libsvm fixture into a fresh mmap store under a
/// temp dir and return the store path.
fn convert_tiny_libsvm(tag: &str) -> String {
    let ds = load_dataset(&fixture("tiny.libsvm"), DataFormat::Libsvm).expect("committed fixture");
    let dir = std::env::temp_dir().join(format!("flexa_int_io_{tag}_{}.fxm", std::process::id()));
    MmapCscStore::write(&dir, &ds.a, ds.labels.as_deref()).expect("write store");
    dir.display().to_string()
}

fn file_spec(path: &str, threads: usize, backend: Backend) -> SolveSpec {
    SolveSpec::builder()
        .problem(ProblemSpec::FromFile {
            kind: FileKind::Lasso,
            path: path.to_string(),
            format: DataFormat::FlexaMmap,
            c: None,
            seed: 7,
        })
        .solver("flexa")
        .threads(threads)
        .backend(backend)
        .max_iters(500)
        .tol(1e-6)
        .build()
        .expect("valid file-backed spec")
}

/// The acceptance gate of the ingest PR: a lasso solve on an mmap-backed
/// matrix converted from the committed libsvm fixture is bitwise
/// identical across worker-thread counts {1, 2, 4} and across the
/// shared/sharded backends — out-of-core storage must not perturb a
/// single bit of the iterate.
#[test]
fn mmap_backed_lasso_is_bitwise_identical_across_threads_and_backends() {
    let store = convert_tiny_libsvm("bitwise");
    let reference = spec::execute(&file_spec(&store, 1, Backend::Shared)).expect("reference run");
    assert!(reference.iters > 0, "reference run did no work");
    assert!(reference.final_merit.is_finite());
    for backend in [Backend::Shared, Backend::Sharded] {
        for threads in [1usize, 2, 4] {
            let run = spec::execute(&file_spec(&store, threads, backend))
                .unwrap_or_else(|e| panic!("{backend:?}/{threads}: {e}"));
            assert_eq!(run.iters, reference.iters, "{backend:?}/{threads}: iteration count");
            assert_eq!(run.x.len(), reference.x.len());
            for (j, (a, b)) in run.x.iter().zip(&reference.x).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{backend:?}/{threads}: x[{j}] drifted ({a:e} vs {b:e})"
                );
            }
        }
    }
}

/// The same solve through the three on-disk representations (libsvm
/// text, the converted store via the portable loader path, and the
/// matrix through `load_dataset`) must agree on the matrix bit-for-bit.
#[test]
fn converted_store_matches_text_loader_bitwise() {
    let text = load_dataset(&fixture("tiny.libsvm"), DataFormat::Libsvm).unwrap();
    let store = convert_tiny_libsvm("roundtrip");
    let mapped = load_dataset(&store, DataFormat::FlexaMmap).unwrap();
    assert_eq!(
        (text.a.nrows(), text.a.ncols(), text.a.nnz()),
        (mapped.a.nrows(), mapped.a.ncols(), mapped.a.nnz())
    );
    for j in 0..text.a.ncols() {
        let (ra, va) = text.a.col(j);
        let (rb, vb) = mapped.a.col(j);
        assert_eq!(ra, rb, "rowind of column {j}");
        for (x, y) in va.iter().zip(vb) {
            assert_eq!(x.to_bits(), y.to_bits(), "value bits in column {j}");
        }
    }
    let (la, lb) = (text.labels.unwrap(), mapped.labels.unwrap());
    assert_eq!(la.len(), lb.len());
    for (x, y) in la.iter().zip(&lb) {
        assert_eq!(x.to_bits(), y.to_bits(), "label bits");
    }
}

/// Every malformed fixture is rejected with a typed error whose message
/// names the offending file — no panics, no silently-wrong matrices.
#[test]
fn malformed_fixtures_all_err_cleanly() {
    let cases: &[(&str, DataFormat, &str)] = &[
        ("bad_index.libsvm", DataFormat::Libsvm, "0-based feature index"),
        ("unsorted.libsvm", DataFormat::Libsvm, "non-ascending feature indices"),
        ("bad_value.libsvm", DataFormat::Libsvm, "non-numeric value"),
        ("truncated.mtx", DataFormat::MatrixMarket, "fewer entries than declared"),
        ("dup_entry.mtx", DataFormat::MatrixMarket, "duplicate coordinate"),
        ("bad_header.mtx", DataFormat::MatrixMarket, "unsupported header"),
        ("out_of_bounds.mtx", DataFormat::MatrixMarket, "row index out of bounds"),
    ];
    for (name, format, why) in cases {
        let path = fixture(name);
        let err = match load_dataset(&path, *format) {
            Ok(_) => panic!("{name} ({why}) loaded without error"),
            Err(e) => e.to_string(),
        };
        assert!(err.contains(name), "{name}: error {err:?} does not name the file");
        assert!(!err.is_empty(), "{name}: empty error message");
    }
}

/// Parse errors carry 1-based line numbers pointing at the bad token.
#[test]
fn parse_errors_carry_line_numbers() {
    for (name, format, line) in [
        ("bad_index.libsvm", DataFormat::Libsvm, ":1:"),
        ("bad_value.libsvm", DataFormat::Libsvm, ":1:"),
        ("out_of_bounds.mtx", DataFormat::MatrixMarket, ":3:"),
    ] {
        let err = load_dataset(&fixture(name), format).unwrap_err().to_string();
        assert!(err.contains(line), "{name}: error {err:?} lacks line marker {line:?}");
    }
}

/// Format auto-detection picks the right loader for both text formats
/// and for a store directory.
#[test]
fn format_detection_covers_all_fixtures() {
    assert_eq!(DataFormat::detect(&fixture("tiny.libsvm")), Some(DataFormat::Libsvm));
    assert_eq!(DataFormat::detect(&fixture("tiny.mtx")), Some(DataFormat::MatrixMarket));
    let store = convert_tiny_libsvm("detect");
    assert_eq!(DataFormat::detect(&store), Some(DataFormat::FlexaMmap));
    let ds = load_dataset(&store, DataFormat::FlexaMmap).unwrap();
    assert!(ds.a.nnz() > 0);
}
