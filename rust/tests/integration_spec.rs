//! Round-trip tests of the redesigned [`SolveSpec`] request API: every
//! shipped TOML config lowers onto specs that survive JSON round-trips
//! exactly, and the CLI-flag and TOML frontends produce *equal* specs
//! for equivalent inputs — one request type behind every surface.

use flexa::cli::{self, args::Args};
use flexa::spec::{self, FrontendOverrides, SolveSpec};
use flexa::util::Json;

fn argv(parts: &[&str]) -> Args {
    let v: Vec<String> = std::iter::once("flexa".to_string())
        .chain(parts.iter().map(|s| s.to_string()))
        .collect();
    Args::parse(&v)
}

/// Every experiment config in `configs/` (serve configs have no
/// `[problem]` table and are covered by the serve tests) lowers onto
/// specs whose JSON encoding is an exact involution: decode(encode(s))
/// == s, and re-encoding reproduces the byte-identical compact string.
#[test]
fn every_shipped_config_round_trips_exactly() {
    let mut paths: Vec<_> = std::fs::read_dir("../configs")
        .expect("configs dir")
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            let name = p.file_name().unwrap_or_default().to_string_lossy().into_owned();
            name.ends_with(".toml") && !name.starts_with("serve")
        })
        .collect();
    paths.sort();
    assert!(!paths.is_empty(), "no experiment configs found");

    let mut seen = 0usize;
    for path in &paths {
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let cfg = flexa::config::ExperimentConfig::from_file(path)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        let specs = spec::specs_from_experiment(&cfg, &FrontendOverrides::default())
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!specs.is_empty(), "{name}: no solvers");
        for s in &specs {
            let text = s.to_json().to_string_compact();
            let back = SolveSpec::from_json(&Json::parse(&text).unwrap())
                .unwrap_or_else(|e| panic!("{name}/{}: {e}", s.name));
            assert_eq!(&back, s, "{name}: decode drifted");
            assert_eq!(back.to_json().to_string_compact(), text, "{name}: re-encode drifted");
            seen += 1;
        }
    }
    assert!(seen >= 5, "expected several shipped specs, saw {seen}");
}

/// The CLI flags (`--threads/--backend/--selection`) and the native TOML
/// keys (`threads`/`backend`/`[selection]`) are two spellings of the
/// same request: lowering either produces equal `SolveSpec` values.
#[test]
fn cli_flags_and_toml_keys_produce_equal_specs() {
    let dir = std::env::temp_dir().join("flexa_spec_frontends_test");
    std::fs::create_dir_all(&dir).unwrap();
    let base = dir.join("base.toml");
    let full = dir.join("full.toml");
    let problem = "\
[problem]\n\
kind = \"lasso\"\n\
m = 30\n\
n = 40\n\
sparsity = 0.1\n\
c = 1.0\n\
seed = 5\n\
\n\
[run]\n\
max_iters = 50\n\
tol = 1e-5\n";
    std::fs::write(
        &base,
        format!("name = \"frontends\"\nsolvers = \"flexa, cdm\"\ncores = 4\n\n{problem}"),
    )
    .unwrap();
    std::fs::write(
        &full,
        format!(
            "name = \"frontends\"\nsolvers = \"flexa, cdm\"\ncores = 4\n\
             threads = 3\nbackend = \"sharded\"\n\n\
             [selection]\nstrategy = \"hybrid\"\nfrac = 0.25\nsigma = 0.5\n\n{problem}"
        ),
    )
    .unwrap();

    let base_s = base.to_string_lossy().into_owned();
    let full_s = full.to_string_lossy().into_owned();
    let (_, from_flags) = cli::solve_specs_from_args(&argv(&[
        "solve",
        "--config",
        &base_s,
        "--threads",
        "3",
        "--backend",
        "sharded",
        "--selection",
        "hybrid:0.25:0.5",
    ]))
    .unwrap();
    let (_, from_toml) =
        cli::solve_specs_from_args(&argv(&["solve", "--config", &full_s])).unwrap();

    assert_eq!(from_flags.len(), 2);
    assert_eq!(from_flags, from_toml, "CLI-flag and TOML frontends diverged");
    // and both survive the wire round-trip identically
    for s in &from_flags {
        let back = SolveSpec::from_json(&s.to_json()).unwrap();
        assert_eq!(&back, s);
    }
}

/// No flags means the config is taken as written — the overrides parser
/// returns all-`None` and the lowered specs match a direct lowering.
#[test]
fn absent_flags_are_no_overrides() {
    let ov = cli::overrides_from_args(&argv(&["solve", "--config", "x.toml"])).unwrap();
    assert!(ov.threads.is_none() && ov.backend.is_none() && ov.selection.is_none());
    assert!(ov.schedule.is_none());
    // bad flag values are rejected at parse time, not mid-solve
    assert!(cli::overrides_from_args(&argv(&["solve", "--backend", "quantum"])).is_err());
    assert!(cli::overrides_from_args(&argv(&["solve", "--selection", "nope:1"])).is_err());
    assert!(cli::overrides_from_args(&argv(&["solve", "--schedule", "chaotic"])).is_err());
    // and the good spellings parse
    let ov = cli::overrides_from_args(&argv(&["solve", "--schedule", "dag:2"])).unwrap();
    assert_eq!(ov.schedule, Some(flexa::coordinator::Schedule::Dag { staleness: 2 }));
}

/// JSON request bodies get the exact builder validation — bad specs are
/// unrepresentable on the wire, with the same error text as the builder.
#[test]
fn json_decoding_validates_like_the_builder() {
    let decode = |s: &str| SolveSpec::from_json(&Json::parse(s).unwrap());
    let lasso = r#""problem":{"kind":"lasso","m":30,"n":40}"#;

    assert!(decode(r#"{"solver":"flexa"}"#).unwrap_err().contains("problem"));
    assert!(decode(&format!("{{{lasso},\"solver\":\"nope\"}}"))
        .unwrap_err()
        .contains("unknown solver"));
    assert!(decode(&format!("{{{lasso},\"solver\":\"fista\",\"backend\":\"sharded\"}}"))
        .unwrap_err()
        .contains("sharded"));
    assert!(decode(&format!("{{{lasso},\"budgets\":{{\"max_iters\":0}}}}"))
        .unwrap_err()
        .contains("max_iters"));
    assert!(decode(&format!("{{{lasso},\"sigma\":1.5}}")).unwrap_err().contains("sigma"));
    assert!(decode(r#"{"problem":{"kind":"lasso","m":30,"n":40,"c":-1.0}}"#)
        .unwrap_err()
        .contains("c must be > 0"));
}

/// The caller-provided-pool entry point (`engine::solve_on`) agrees
/// bitwise with the `SolveSpec` path it backs.
#[test]
fn pool_entry_point_matches_spec_execution() {
    let spec = SolveSpec::builder()
        .problem(flexa::config::ProblemSpec::Lasso {
            m: 30,
            n: 40,
            sparsity: 0.1,
            c: 1.0,
            seed: 11,
        })
        .solver("flexa")
        .threads(2)
        .max_iters(25)
        .tol(0.0)
        .build()
        .unwrap();
    let problem = spec::build_problem(&spec.problem).unwrap();
    let model = flexa::simulator::CostModel::default();
    let via_spec = spec::execute_prepared(
        &spec,
        problem.as_ref(),
        spec::ExecOptions { pool: None, x0: None, model },
    )
    .unwrap();

    let sspec = spec
        .lower(flexa::coordinator::TermMetric::RelErr, model)
        .unwrap();
    let pool = flexa::parallel::WorkerPool::new(2);
    let x0 = vec![0.0; problem.n()];
    let via_pool = flexa::engine::solve_on(problem.as_ref(), &x0, &sspec, Some(&pool));

    assert_eq!(via_spec.x, via_pool.x);
    assert_eq!(via_spec.final_obj, via_pool.final_obj);
    assert_eq!(via_spec.iters, via_pool.iters);
}
