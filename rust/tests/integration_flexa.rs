//! Integration: the coordinator algorithms against every problem family —
//! convergence to known optima, stationarity of limit points, and the
//! cross-algorithm consistency claims of the paper (Theorems 1–3).

use flexa::coordinator::{
    flexa as run_flexa, gauss_jacobi, CommonOptions, FlexaOptions, GaussJacobiOptions,
    SelectionSpec, StepRule, TermMetric,
};
use flexa::datagen::{logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset};
use flexa::problems::{
    GroupLassoProblem, LassoProblem, LogisticProblem, NonconvexQpProblem, Problem,
};

fn common(name: &str, tol: f64, term: TermMetric) -> CommonOptions {
    CommonOptions {
        max_iters: 20_000,
        max_wall_s: 60.0,
        tol,
        term,
        name: name.into(),
        ..Default::default()
    }
}

#[test]
fn flexa_reaches_high_accuracy_on_lasso() {
    let p = LassoProblem::from_instance(nesterov_lasso(90, 120, 0.1, 1.0, 1));
    let o = FlexaOptions {
        common: common("flexa", 1e-8, TermMetric::RelErr),
        selection: SelectionSpec::sigma(0.5),
        inexact: None,
    };
    let r = run_flexa(&p, &vec![0.0; p.n()], &o);
    assert!(r.converged(), "{:?} re={}", r.stop, r.final_rel_err);
    // the limit point is stationary: merit ≈ 0 (gradient units, so a few
    // orders looser than the re(x) tolerance)
    assert!(r.final_merit < 1e-3, "merit {}", r.final_merit);
}

#[test]
fn all_sigmas_converge_to_same_optimum() {
    let p = LassoProblem::from_instance(nesterov_lasso(60, 90, 0.2, 1.0, 2));
    let mut objs = Vec::new();
    for sigma in [0.0, 0.3, 0.5, 0.9] {
        let o = FlexaOptions {
            common: common(&format!("s{sigma}"), 1e-7, TermMetric::RelErr),
            selection: SelectionSpec::sigma(sigma),
            inexact: None,
        };
        let r = run_flexa(&p, &vec![0.0; p.n()], &o);
        assert!(r.converged(), "sigma={sigma} {:?}", r.stop);
        objs.push(r.final_obj);
    }
    let vs = p.v_star().unwrap();
    for o in &objs {
        assert!((o - vs).abs() / vs < 1e-6, "obj {o} vs V* {vs}");
    }
}

#[test]
fn flexa_and_gj_agree_on_logistic() {
    // Algorithms 1 and 3 must find the same stationary value
    let inst = logistic_like(LogisticPreset::Gisette, 0.015, 8);
    let p = LogisticProblem::from_instance(inst);
    let x0 = vec![0.0; p.n()];
    let mut c1 = common("flexa", 1e-6, TermMetric::Merit);
    c1.merit_every = 1;
    let r1 = run_flexa(
        &p,
        &x0,
        &FlexaOptions { common: c1, selection: SelectionSpec::sigma(0.5), inexact: None },
    );
    let mut c2 = common("gj", 1e-6, TermMetric::Merit);
    c2.merit_every = 1;
    let r2 = gauss_jacobi(
        &p,
        &x0,
        &GaussJacobiOptions {
            common: c2,
            selection: Some(SelectionSpec::sigma(0.5)),
            processors: 4,
        },
    );
    assert!(r1.final_merit < 1e-2, "flexa merit {}", r1.final_merit);
    assert!(r2.final_merit < 1e-2, "gj merit {}", r2.final_merit);
    assert!(
        (r1.final_obj - r2.final_obj).abs() / r1.final_obj.abs() < 1e-3,
        "objectives diverge: {} vs {}",
        r1.final_obj,
        r2.final_obj
    );
}

#[test]
fn nonconvex_reaches_stationarity_with_box_respected() {
    let p = NonconvexQpProblem::from_instance(nonconvex_qp(60, 80, 0.1, 10.0, 100.0, 1.0, 3));
    let mut c = common("flexa-ncvx", 1e-4, TermMetric::Merit);
    c.merit_every = 1;
    let o = FlexaOptions { common: c, selection: SelectionSpec::sigma(0.5), inexact: None };
    let r = run_flexa(&p, &vec![0.0; p.n()], &o);
    assert!(r.final_merit < 1e-3, "merit {} ({:?})", r.final_merit, r.stop);
    assert!(r.x.iter().all(|&v| v.abs() <= 1.0 + 1e-10), "box violated");
    // with c̄ this large the objective should exploit the box: solution is
    // not identically zero
    assert!(r.x.iter().any(|&v| v.abs() > 1e-3), "trivial solution");
}

#[test]
fn group_lasso_exact_on_orthogonal_design() {
    // A = I: the group-LASSO solution is the block soft-threshold of b in
    // closed form — FLEXA must hit it to machine precision.
    use flexa::linalg::{vector, BlockPartition, DenseMatrix, Matrix};
    let n = 6;
    let a = DenseMatrix::from_fn(n, n, |i, j| if i == j { 1.0 } else { 0.0 });
    let b = vec![2.0, 1.0, 0.2, 0.1, -3.0, 0.0];
    let p = GroupLassoProblem::new(
        Matrix::Dense(a),
        b.clone(),
        1.0,
        BlockPartition::uniform(n, 2),
    );
    let mut c = common("flexa-group-ortho", 1e-10, TermMetric::Merit);
    c.merit_every = 1;
    let o = FlexaOptions { common: c, selection: SelectionSpec::full_jacobi(), inexact: None };
    let r = run_flexa(&p, &vec![0.0; n], &o);
    assert!(r.converged(), "{:?} merit={}", r.stop, r.final_merit);
    for blk in 0..3 {
        let lo = blk * 2;
        let bi = [b[lo], b[lo + 1]];
        let mut expect = [0.0; 2];
        vector::block_soft_threshold(&bi, 0.5, &mut expect); // prox of (c/2)‖·‖
        assert!((r.x[lo] - expect[0]).abs() < 1e-7, "block {blk}");
        assert!((r.x[lo + 1] - expect[1]).abs() < 1e-7, "block {blk}");
    }
}

#[test]
fn group_lasso_blocks_converge() {
    // Nesterov instances are ill-conditioned for the group norm (weakly
    // active blocks ⇒ slow tail); assert solid merit reduction + structure
    let p = GroupLassoProblem::from_instance(nesterov_lasso(60, 80, 0.1, 1.0, 5), 4);
    let mut c = common("flexa-group", 5e-2, TermMetric::Merit);
    c.merit_every = 1;
    c.stepsize = StepRule::Constant { gamma: 0.9 };
    let o = FlexaOptions { common: c, selection: SelectionSpec::sigma(0.5), inexact: None };
    let r = run_flexa(&p, &vec![0.0; p.n()], &o);
    assert!(r.final_merit < 0.2, "merit {} ({:?})", r.final_merit, r.stop);
    // group sparsity: whole blocks are (numerically) zero
    let blocks = p.blocks();
    let zero_blocks = (0..blocks.n_blocks())
        .filter(|&i| blocks.range(i).all(|j| r.x[j].abs() < 1e-6))
        .count();
    assert!(zero_blocks > 0, "no block-sparse structure in the solution");
}

#[test]
fn gj_select_no_flop_waste_on_logistic() {
    // the paper's §VI-B observation: greedy selection helps on the highly
    // nonlinear logistic objective
    let inst = logistic_like(LogisticPreset::Gisette, 0.015, 13);
    let p = LogisticProblem::from_instance(inst);
    let x0 = vec![0.0; p.n()];
    let mk = |name: &str| {
        let mut c = common(name, 5e-6, TermMetric::Merit);
        c.merit_every = 1;
        c.max_iters = 4000;
        c
    };
    let plain = gauss_jacobi(
        &p,
        &x0,
        &GaussJacobiOptions { common: mk("gj"), selection: None, processors: 2 },
    );
    let selective = gauss_jacobi(
        &p,
        &x0,
        &GaussJacobiOptions {
            common: mk("gj-sel"),
            selection: Some(SelectionSpec::sigma(0.5)),
            processors: 2,
        },
    );
    assert!(plain.final_merit < 1e-4 && selective.final_merit < 1e-4);
    // selective must stay within a small constant factor of plain GJ in
    // flops (the Jacobi prepass that computes E_i costs ~one weighted
    // sweep; with a lightly-regularized instance most blocks stay selected)
    assert!(
        selective.flops <= plain.flops * 2.5,
        "selection wasted flops: {} vs {}",
        selective.flops,
        plain.flops
    );
}

#[test]
fn discarded_iterations_counted_when_tau_doubles() {
    // force τ rejects: start τ absurdly low so early steps overshoot
    let p = LassoProblem::from_instance(nesterov_lasso(40, 120, 0.4, 0.2, 21));
    let mut c = common("flexa-tau", 1e-6, TermMetric::RelErr);
    c.tau = Some(flexa::coordinator::TauOptions::paper(1e-8, 0.0));
    c.stepsize = StepRule::Constant { gamma: 1.0 };
    c.max_iters = 500;
    let o = FlexaOptions { common: c, selection: SelectionSpec::full_jacobi(), inexact: None };
    let r = run_flexa(&p, &vec![0.0; p.n()], &o);
    assert!(r.discarded > 0, "expected τ-doubling discards");
}

/// FLEXA iterates must be **bitwise-identical** for every thread count
/// (fixed chunk boundaries + ordered reductions in `flexa::parallel`).
fn assert_flexa_bitwise_deterministic(p: &dyn Problem, term: TermMetric, max_iters: usize) {
    let mk = |threads: usize| {
        let mut c = common("t", 1e-9, term);
        c.threads = threads;
        c.max_iters = max_iters;
        c.tol = 0.0;
        c.merit_every = 1;
        FlexaOptions { common: c, selection: SelectionSpec::sigma(0.5), inexact: None }
    };
    let r1 = run_flexa(p, &vec![0.0; p.n()], &mk(1));
    for threads in [2usize, 4] {
        let rt = run_flexa(p, &vec![0.0; p.n()], &mk(threads));
        assert_eq!(r1.x, rt.x, "iterates diverged at threads={threads}");
        assert_eq!(r1.iters, rt.iters, "iteration count diverged at threads={threads}");
        assert_eq!(r1.final_obj, rt.final_obj, "objective diverged at threads={threads}");
    }
}

/// Same bitwise guarantee for Gauss-Jacobi with selection (Algorithm 3),
/// whose prepass runs on the pool.
fn assert_gj_bitwise_deterministic(p: &dyn Problem, term: TermMetric, max_iters: usize) {
    let mk = |threads: usize| {
        let mut c = common("t", 1e-9, term);
        c.threads = threads;
        c.max_iters = max_iters;
        c.tol = 0.0;
        c.merit_every = 1;
        GaussJacobiOptions {
            common: c,
            selection: Some(SelectionSpec::sigma(0.5)),
            processors: 4,
        }
    };
    let r1 = gauss_jacobi(p, &vec![0.0; p.n()], &mk(1));
    for threads in [2usize, 4] {
        let rt = gauss_jacobi(p, &vec![0.0; p.n()], &mk(threads));
        assert_eq!(r1.x, rt.x, "GJ iterates diverged at threads={threads}");
        assert_eq!(r1.iters, rt.iters);
        assert_eq!(r1.final_obj, rt.final_obj);
    }
}

#[test]
fn threaded_flexa_bitwise_identical_on_lasso() {
    let p = LassoProblem::from_instance(nesterov_lasso(50, 70, 0.1, 1.0, 17));
    assert_flexa_bitwise_deterministic(&p, TermMetric::RelErr, 200);
}

#[test]
fn threaded_flexa_bitwise_identical_on_logistic() {
    let p = LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.012, 9));
    assert_flexa_bitwise_deterministic(&p, TermMetric::Merit, 60);
}

#[test]
fn threaded_flexa_bitwise_identical_on_nonconvex_qp() {
    let p = NonconvexQpProblem::from_instance(nonconvex_qp(40, 60, 0.1, 10.0, 50.0, 1.0, 12));
    assert_flexa_bitwise_deterministic(&p, TermMetric::Merit, 100);
}

#[test]
fn threaded_gj_bitwise_identical_on_lasso() {
    let p = LassoProblem::from_instance(nesterov_lasso(50, 70, 0.1, 1.0, 18));
    assert_gj_bitwise_deterministic(&p, TermMetric::RelErr, 100);
}

#[test]
fn threaded_gj_bitwise_identical_on_logistic() {
    let p = LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.012, 10));
    assert_gj_bitwise_deterministic(&p, TermMetric::Merit, 40);
}

#[test]
fn threaded_gj_bitwise_identical_on_nonconvex_qp() {
    let p = NonconvexQpProblem::from_instance(nonconvex_qp(40, 60, 0.1, 10.0, 50.0, 1.0, 13));
    assert_gj_bitwise_deterministic(&p, TermMetric::Merit, 60);
}

#[test]
fn solve_spawns_workers_once_not_per_iteration() {
    // pool lifecycle at the solver level: a 300-iteration threads=4 solve
    // may spawn at most a handful of OS threads (3 for its own pool, plus
    // whatever concurrently-running tests spawn) — a spawn-per-iteration
    // implementation would add ≥ 900 to the global counter.
    use flexa::parallel::WorkerPool;
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 19));
    let mut c = common("pool-lifecycle", 1e-9, TermMetric::RelErr);
    c.threads = 4;
    c.max_iters = 300;
    c.tol = 0.0;
    let before = WorkerPool::os_threads_spawned_total();
    let r = run_flexa(
        &p,
        &vec![0.0; p.n()],
        &FlexaOptions { common: c, selection: SelectionSpec::sigma(0.5), inexact: None },
    );
    let spawned = WorkerPool::os_threads_spawned_total() - before;
    assert_eq!(r.iters, 300);
    assert!(
        spawned < r.iters,
        "suspiciously many spawns ({spawned}) for a {}-iteration solve — \
         workers must be created once per solve, not per iteration",
        r.iters
    );
}

#[test]
fn time_budget_respected() {
    let p = LassoProblem::from_instance(nesterov_lasso(200, 4000, 0.3, 1.0, 7));
    let mut c = common("budget", 0.0, TermMetric::RelErr);
    c.max_wall_s = 0.3;
    c.max_iters = usize::MAX / 2;
    let o = FlexaOptions { common: c, selection: SelectionSpec::full_jacobi(), inexact: None };
    let t = std::time::Instant::now();
    let r = run_flexa(&p, &vec![0.0; p.n()], &o);
    assert_eq!(r.stop, flexa::coordinator::StopReason::TimeBudget);
    assert!(t.elapsed().as_secs_f64() < 5.0, "budget ignored");
}
