//! Golden-trace regression harness: the first 5 iterates of every solver
//! family on small seeded instances of **all six** problem kinds (lasso,
//! group-lasso, logistic, svm, nonconvex-qp, dictionary sparse coding),
//! pinned **bitwise** (f64 bit patterns, hex-serialized) against
//! `tests/fixtures/golden_*.txt` — so a future refactor cannot silently
//! drift numerics — and pinned across the engine's two data-plane
//! backends and the worker-thread axis:
//!
//! * `shared` ≡ `sharded` bitwise for the scan/sweep families (the
//!   column-distributed owner-computes path with its fixed-order
//!   allreduce must be iterate-preserving);
//! * every `threads` value produces the same bits (the repo-wide
//!   determinism contract).
//!
//! The CI matrix drives the axes through env vars:
//! `FLEXA_TEST_BACKEND` = `shared` | `sharded` | `both` (default `both`)
//! and `FLEXA_TEST_THREADS` = comma list (default `1,2,4`).
//!
//! Missing fixture files are **generated** (and reported on stderr) so the
//! harness bootstraps on a fresh developer machine; with
//! `FLEXA_GOLDEN_REQUIRE=1` (set by the CI golden-matrix job whenever the
//! checkout ships committed fixtures) a missing file is a hard **failure**
//! instead — the drift check is armed and can never silently re-bootstrap.
//! See `tests/fixtures/README.md`.
//!
//! The **fast numerics tier** (`--numerics fast`) rides the same matrix
//! in relative-error mode: its iterates must land within
//! `FLEXA_GOLDEN_TOL` (default `1e-6`, relative with an absolute floor)
//! of the exact-tier reference — the committed fixture when one exists,
//! an in-process exact run otherwise. The exact tier itself is **always**
//! compared hex-bit; the tolerance mode exists only for the tier whose
//! contract is "re-associated within a kernel call", never to loosen the
//! default tier's bitwise pin.

use flexa::coordinator::{Backend, CommonOptions, NumericsTier, Schedule, TermMetric};
use flexa::datagen::{
    dictionary_instance, logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset,
};
use flexa::engine::{self, SolverSpec};
use flexa::problems::{
    DictionaryCodesProblem, GroupLassoProblem, LassoProblem, LogisticProblem, NonconvexQpProblem,
    Problem, SvmProblem,
};
use std::path::PathBuf;

/// Iterates pinned per (problem, family).
const GOLDEN_ITERS: usize = 5;
/// Simulated cores: also the shard count of the sharded backend runs.
const CORES: usize = 4;

fn fixtures_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures")
}

fn threads_axis() -> Vec<usize> {
    std::env::var("FLEXA_TEST_THREADS")
        .ok()
        .map(|v| {
            v.split(',')
                .filter_map(|t| t.trim().parse::<usize>().ok())
                .filter(|&t| t >= 1)
                .collect::<Vec<_>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![1, 2, 4])
}

/// Whether a missing fixture is a hard failure (armed drift check)
/// rather than a bootstrap. Empty / "0" count as unset so a matrix job
/// can template the variable away.
fn golden_fixtures_required() -> bool {
    matches!(std::env::var("FLEXA_GOLDEN_REQUIRE"), Ok(v) if !v.is_empty() && v != "0")
}

fn backends_axis() -> Vec<Backend> {
    match std::env::var("FLEXA_TEST_BACKEND").as_deref() {
        Ok("shared") => vec![Backend::Shared],
        Ok("sharded") => vec![Backend::Sharded],
        _ => vec![Backend::Shared, Backend::Sharded],
    }
}

/// One solver family of the golden matrix.
struct Family {
    name: &'static str,
    /// Whether the sharded data plane covers it (scan/sweep families).
    sharded: bool,
}

const fn fam(name: &'static str, sharded: bool) -> Family {
    Family { name, sharded }
}

/// The families pinned on each problem kind. ADMM assumes the residual
/// consensus form `F = ‖Ax − b‖²` (lasso, group-lasso, dictionary —
/// the same probe the CLI and engine use); GRock/greedy-1BCD pin τ = 0,
/// which the nonconvex QP's convexity floor (τ > 2c̄) forbids and which
/// is ill-posed for the ℓ2-SVM (the active-hinge generalized-Hessian
/// diagonal can vanish). The engine floors a pinned τ at
/// `Problem::tau_min`, so those combinations run safely — but they are
/// not paper configurations, so the pinned matrix leaves them out.
fn families_for(kind: &str) -> Vec<Family> {
    let mut fams = vec![
        fam("flexa", true),
        fam("gauss-jacobi", true),
        fam("gj-flexa", true),
        fam("cdm", true),
        fam("fista", false),
        fam("sparsa", false),
    ];
    if kind != "nonconvex-qp" && kind != "svm" {
        fams.push(fam("grock", true));
        fams.push(fam("greedy-1bcd", true));
    }
    if flexa::problems::is_residual_form(build_problem(kind).as_ref()) {
        fams.push(fam("admm", false));
    }
    fams
}

fn build_problem(kind: &str) -> Box<dyn Problem> {
    match kind {
        "lasso" => Box::new(LassoProblem::from_instance(nesterov_lasso(30, 40, 0.1, 1.0, 4242))),
        "group-lasso" => Box::new(GroupLassoProblem::from_instance(
            nesterov_lasso(30, 40, 0.1, 1.0, 4242),
            4,
        )),
        "logistic" => Box::new(LogisticProblem::from_instance(logistic_like(
            LogisticPreset::Gisette,
            0.008,
            4242,
        ))),
        "svm" => {
            let inst = logistic_like(LogisticPreset::Gisette, 0.008, 4242);
            Box::new(SvmProblem::new(inst.y, &inst.labels, inst.c.max(0.1)))
        }
        "nonconvex-qp" => Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
            30, 40, 0.1, 10.0, 50.0, 1.0, 4242,
        ))),
        "dictionary" => Box::new(DictionaryCodesProblem::from_instance(&dictionary_instance(
            10, 6, 8, 0.3, 0.01, 4242,
        ))),
        other => panic!("unknown golden problem kind {other:?}"),
    }
}

fn spec_for(
    family: &str,
    kind: &str,
    backend: Backend,
    threads: usize,
    max_iters: usize,
) -> SolverSpec {
    spec_for_tier(family, kind, backend, threads, max_iters, NumericsTier::Exact)
}

fn spec_for_tier(
    family: &str,
    kind: &str,
    backend: Backend,
    threads: usize,
    max_iters: usize,
    numerics: NumericsTier,
) -> SolverSpec {
    let term = if kind == "lasso" { TermMetric::RelErr } else { TermMetric::Merit };
    let common = CommonOptions {
        max_iters,
        max_wall_s: f64::MAX,
        tol: 0.0, // never converge inside the pinned window
        term,
        cores: CORES,
        threads,
        trace_every: max_iters,
        backend,
        numerics,
        name: format!("golden-{family}"),
        ..Default::default()
    };
    SolverSpec::from_name(family, common, None, 0.5, CORES)
        .unwrap_or_else(|e| panic!("{family}: {e}"))
}

/// `x^1 … x^5` for one configuration: the engine is deterministic, so the
/// `max_iters = k` run reproduces the first `k` iterations of any longer
/// run — each final iterate is one golden line.
fn iterates(
    problem: &dyn Problem,
    family: &str,
    kind: &str,
    backend: Backend,
    threads: usize,
) -> Vec<Vec<f64>> {
    iterates_tier(problem, family, kind, backend, threads, NumericsTier::Exact)
}

fn iterates_tier(
    problem: &dyn Problem,
    family: &str,
    kind: &str,
    backend: Backend,
    threads: usize,
    numerics: NumericsTier,
) -> Vec<Vec<f64>> {
    let x0 = vec![0.0; problem.n()];
    (1..=GOLDEN_ITERS)
        .map(|k| {
            engine::solve(problem, &x0, &spec_for_tier(family, kind, backend, threads, k, numerics))
                .x
        })
        .collect()
}

fn to_hex_lines(trace: &[Vec<f64>]) -> String {
    let mut out = String::new();
    for x in trace {
        let words: Vec<String> = x.iter().map(|v| format!("{:016x}", v.to_bits())).collect();
        out.push_str(&words.join(" "));
        out.push('\n');
    }
    out
}

fn assert_bits_equal(a: &[Vec<f64>], b: &[Vec<f64>], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: iterate count");
    for (k, (xa, xb)) in a.iter().zip(b).enumerate() {
        assert_eq!(xa.len(), xb.len(), "{what}: x^{} dimension", k + 1);
        for i in 0..xa.len() {
            assert!(
                xa[i].to_bits() == xb[i].to_bits(),
                "{what}: x^{}[{i}] {:e} != {:e} (bits {:016x} vs {:016x})",
                k + 1,
                xa[i],
                xb[i],
                xa[i].to_bits(),
                xb[i].to_bits()
            );
        }
    }
}

/// Compare against (or bootstrap) the committed fixture.
fn check_fixture(kind: &str, family: &str, reference: &[Vec<f64>]) {
    let dir = fixtures_dir();
    let path = dir.join(format!("golden_{kind}_{family}.txt"));
    let rendered = to_hex_lines(reference);
    match std::fs::read_to_string(&path) {
        Ok(stored) => {
            // newline-insensitive compare (editors may add a trailing \n)
            assert_eq!(
                stored.trim_end(),
                rendered.trim_end(),
                "golden fixture drift: {} no longer matches the engine's first \
                 {GOLDEN_ITERS} iterates — a refactor changed numerics. If the change \
                 is intentional, delete the fixture and rerun to regenerate.",
                path.display()
            );
        }
        Err(_) => {
            // the CI golden-matrix job sets FLEXA_GOLDEN_REQUIRE=1
            // whenever the checkout ships committed fixtures, turning a
            // silently-bootstrapping run into a hard failure (a fresh
            // checkout must have the history to check — this is what
            // catches a new family added without committing its fixture)
            assert!(
                !golden_fixtures_required(),
                "golden fixture {} is missing but FLEXA_GOLDEN_REQUIRE is set — \
                 the committed history check cannot run; regenerate the fixture \
                 (run this suite without the variable) and commit it",
                path.display()
            );
            let _ = std::fs::create_dir_all(&dir);
            std::fs::write(&path, &rendered)
                .unwrap_or_else(|e| panic!("cannot write fixture {}: {e}", path.display()));
            eprintln!("generated golden fixture {} (commit it to arm the check)", path.display());
        }
    }
}

/// The full golden matrix for one problem kind.
fn golden_matrix(kind: &str) {
    let problem = build_problem(kind);
    let backends = backends_axis();
    let threads = threads_axis();
    for family in families_for(kind) {
        let run_backends: Vec<Backend> = backends
            .iter()
            .copied()
            .filter(|b| *b == Backend::Shared || family.sharded)
            .collect();
        if run_backends.is_empty() {
            continue; // sharded-only lane, full-vector family
        }
        // reference trace: first backend × first thread count
        let reference =
            iterates(problem.as_ref(), family.name, kind, run_backends[0], threads[0]);
        assert_eq!(reference.len(), GOLDEN_ITERS);

        for &backend in &run_backends {
            for &t in &threads {
                if backend == run_backends[0] && t == threads[0] {
                    continue;
                }
                let got = iterates(problem.as_ref(), family.name, kind, backend, t);
                assert_bits_equal(
                    &reference,
                    &got,
                    &format!("{kind}/{} @ backend={:?} threads={t}", family.name, backend),
                );
            }
        }
        check_fixture(kind, family.name, &reference);
    }
}

/// Relative tolerance for the fast-tier comparison (`FLEXA_GOLDEN_TOL`,
/// default `1e-6`). Applied per element as
/// `|fast − exact| ≤ tol · max(|exact|, |fast|, 1)` — the unit floor
/// doubles as the absolute tolerance around zero entries.
fn golden_tol() -> f64 {
    std::env::var("FLEXA_GOLDEN_TOL")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .filter(|t| t.is_finite() && *t > 0.0)
        .unwrap_or(1e-6)
}

/// Parse a golden fixture back into iterate vectors; `None` when any
/// token is malformed (e.g. a concurrently bootstrapping writer), so the
/// caller falls back to an in-process exact reference.
fn from_hex_lines(text: &str) -> Option<Vec<Vec<f64>>> {
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(|l| {
            l.split_whitespace()
                .map(|t| u64::from_str_radix(t, 16).ok().map(f64::from_bits))
                .collect::<Option<Vec<f64>>>()
        })
        .collect()
}

fn assert_within_tol(reference: &[Vec<f64>], got: &[Vec<f64>], tol: f64, what: &str) {
    assert_eq!(reference.len(), got.len(), "{what}: iterate count");
    for (k, (xr, xg)) in reference.iter().zip(got).enumerate() {
        assert_eq!(xr.len(), xg.len(), "{what}: x^{} dimension", k + 1);
        for i in 0..xr.len() {
            let scale = xr[i].abs().max(xg[i].abs()).max(1.0);
            assert!(
                (xr[i] - xg[i]).abs() <= tol * scale,
                "{what}: x^{}[{i}] fast tier drifted past FLEXA_GOLDEN_TOL = {tol:e} \
                 ({:e} vs exact {:e})",
                k + 1,
                xg[i],
                xr[i]
            );
        }
    }
}

/// Fast-tier matrix for one problem kind: every family's fast-tier
/// iterates must land within [`golden_tol`] of the exact-tier reference
/// (the committed fixture when one parses cleanly, an in-process exact
/// run otherwise). The exact tier's own hex-bit pin is untouched.
fn golden_matrix_fast(kind: &str) {
    let problem = build_problem(kind);
    let tol = golden_tol();
    for family in families_for(kind) {
        let fast = iterates_tier(
            problem.as_ref(),
            family.name,
            kind,
            Backend::Shared,
            1,
            NumericsTier::Fast,
        );
        let path = fixtures_dir().join(format!("golden_{kind}_{}.txt", family.name));
        let reference = std::fs::read_to_string(&path)
            .ok()
            .and_then(|stored| from_hex_lines(&stored))
            .filter(|r| r.len() == GOLDEN_ITERS && r.iter().all(|x| x.len() == problem.n()))
            .unwrap_or_else(|| {
                iterates_tier(
                    problem.as_ref(),
                    family.name,
                    kind,
                    Backend::Shared,
                    1,
                    NumericsTier::Exact,
                )
            });
        assert_within_tol(&reference, &fast, tol, &format!("{kind}/{} fast-tier", family.name));
    }
}

#[test]
fn golden_traces_lasso() {
    golden_matrix("lasso");
}

#[test]
fn golden_traces_group_lasso() {
    golden_matrix("group-lasso");
}

#[test]
fn golden_traces_logistic() {
    golden_matrix("logistic");
}

#[test]
fn golden_traces_svm() {
    golden_matrix("svm");
}

#[test]
fn golden_traces_nonconvex_qp() {
    golden_matrix("nonconvex-qp");
}

#[test]
fn golden_traces_dictionary() {
    golden_matrix("dictionary");
}

#[test]
fn golden_fast_tier_lasso() {
    golden_matrix_fast("lasso");
}

#[test]
fn golden_fast_tier_group_lasso() {
    golden_matrix_fast("group-lasso");
}

#[test]
fn golden_fast_tier_logistic() {
    golden_matrix_fast("logistic");
}

#[test]
fn golden_fast_tier_svm() {
    golden_matrix_fast("svm");
}

#[test]
fn golden_fast_tier_nonconvex_qp() {
    golden_matrix_fast("nonconvex-qp");
}

#[test]
fn golden_fast_tier_dictionary() {
    golden_matrix_fast("dictionary");
}

/// Schedule axis for the dag determinism matrix:
/// `FLEXA_TEST_SCHEDULE` = comma list of schedule grammar strings
/// (`dag`, `dag:0`, `dag:3`, `dag:inf`, …; default `dag:1`). The CI
/// schedule-matrix job sweeps the staleness endpoints through this.
fn schedule_axis() -> Vec<Schedule> {
    std::env::var("FLEXA_TEST_SCHEDULE")
        .ok()
        .map(|v| {
            v.split(',')
                .map(str::trim)
                .filter(|t| !t.is_empty())
                .map(|t| {
                    Schedule::parse(t).unwrap_or_else(|e| panic!("FLEXA_TEST_SCHEDULE: {e}"))
                })
                .collect::<Vec<_>>()
        })
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| vec![Schedule::Dag { staleness: 1 }])
}

/// Banded sparse LASSO for the schedule matrix: column supports overlap
/// without being complete, so the dependency graph has several blocks
/// per color and the epoch executor genuinely interleaves — the regime
/// the determinism pin must survive.
fn banded_csc_lasso() -> LassoProblem {
    use flexa::linalg::{CscMatrix, Matrix};
    let mut t = Vec::new();
    for j in 0..24usize {
        for d in 0..3usize {
            t.push(((j * 2 + d * 5) % 30, j, 1.0 + (j + d) as f64 * 0.1));
        }
    }
    let a = Matrix::Sparse(CscMatrix::from_triplets(30, 24, &t));
    let b: Vec<f64> = (0..30).map(|r| (r % 7) as f64 * 0.3 - 1.0).collect();
    LassoProblem::new(a, b, 0.05, None)
}

/// The dag schedule rides the golden determinism axes: for every
/// Jacobi-merge family the first [`GOLDEN_ITERS`] iterates under
/// `--schedule dag[:N]` are **bitwise identical** across the thread
/// axis, across both data-plane backends, and across a replay of the
/// same configuration. (The dag is a *different* deterministic
/// iteration than barrier — no cross-schedule fixture is shared — so
/// the pin here is self-referential rather than fixture-backed, plus a
/// converged-objective agreement check against the barrier schedule.)
#[test]
fn golden_dag_schedule_is_deterministic_across_the_matrix() {
    let problem = banded_csc_lasso();
    let x0 = vec![0.0; problem.n()];
    let threads = threads_axis();
    let backends = backends_axis();
    for schedule in schedule_axis() {
        let spec = |family: &str, backend: Backend, t: usize, max_iters: usize| {
            let common = CommonOptions {
                max_iters,
                max_wall_s: f64::MAX,
                tol: 0.0,
                term: TermMetric::Merit,
                cores: CORES,
                threads: t,
                trace_every: max_iters,
                backend,
                schedule,
                name: format!("golden-sched-{family}"),
                ..Default::default()
            };
            SolverSpec::from_name(family, common, None, 0.5, CORES)
                .unwrap_or_else(|e| panic!("{family}: {e}"))
        };
        for family in ["flexa", "grock", "greedy-1bcd"] {
            let run = |backend: Backend, t: usize| -> Vec<Vec<f64>> {
                (1..=GOLDEN_ITERS)
                    .map(|k| engine::solve(&problem, &x0, &spec(family, backend, t, k)).x)
                    .collect()
            };
            let reference = run(backends[0], threads[0]);
            for &backend in &backends {
                for &t in &threads {
                    if backend == backends[0] && t == threads[0] {
                        continue;
                    }
                    assert_bits_equal(
                        &reference,
                        &run(backend, t),
                        &format!(
                            "{family} @ schedule={} backend={backend:?} threads={t}",
                            schedule.name()
                        ),
                    );
                }
            }
            // replay: same configuration, same bits
            assert_bits_equal(
                &reference,
                &run(backends[0], threads[0]),
                &format!("{family} @ schedule={} replay", schedule.name()),
            );
        }

        // tolerance mode: barrier and dag are different iterations of the
        // same convex problem — driven to a tight merit tolerance they
        // must agree on the objective they converge to
        let converge = |schedule: Schedule| {
            let common = CommonOptions {
                max_iters: 20_000,
                max_wall_s: f64::MAX,
                tol: 1e-8,
                term: TermMetric::Merit,
                cores: CORES,
                threads: threads[0],
                trace_every: 20_000,
                schedule,
                name: format!("golden-sched-conv@{}", schedule.name()),
                ..Default::default()
            };
            let spec = SolverSpec::from_name("flexa", common, None, 0.5, CORES)
                .unwrap_or_else(|e| panic!("flexa: {e}"));
            engine::solve(&problem, &x0, &spec)
        };
        let barrier = converge(Schedule::Barrier);
        let dag = converge(schedule);
        assert!(barrier.converged(), "barrier flexa did not converge: {:?}", barrier.stop);
        assert!(
            dag.converged(),
            "dag flexa did not converge under {}: {:?}",
            schedule.name(),
            dag.stop
        );
        let scale = barrier.final_obj.abs().max(1.0);
        assert!(
            (barrier.final_obj - dag.final_obj).abs() <= 1e-6 * scale,
            "schedules disagree at convergence: barrier V = {:e}, {} V = {:e}",
            barrier.final_obj,
            schedule.name(),
            dag.final_obj
        );
    }
}

#[test]
fn golden_run_is_a_prefix_of_a_longer_run() {
    // the harness premise: a max_iters = k solve reproduces the first k
    // iterations of a longer run. The trace does not store iterates, but
    // the objective V(x^k) is a deterministic function of the iterate, so
    // comparing the long run's per-iteration objective bits against each
    // truncated run's final objective pins the premise for every k.
    let problem = build_problem("lasso");
    let x0 = vec![0.0; problem.n()];
    let mut long_spec = spec_for("flexa", "lasso", Backend::Shared, 1, 9);
    long_spec.common.trace_every = 1;
    let long = engine::solve(problem.as_ref(), &x0, &long_spec);
    assert_eq!(long.iters, 9);
    for k in 1..=GOLDEN_ITERS {
        let short = engine::solve(
            problem.as_ref(),
            &x0,
            &spec_for("flexa", "lasso", Backend::Shared, 1, k),
        );
        assert_eq!(short.iters, k);
        let pt = long
            .trace
            .points
            .iter()
            .find(|p| p.iter == k)
            .unwrap_or_else(|| panic!("long run has no trace point at iter {k}"));
        assert!(
            short.final_obj.to_bits() == pt.obj.to_bits(),
            "max_iters = {k} does not reproduce the long run's iterate \
             (V = {:e} vs {:e})",
            short.final_obj,
            pt.obj
        );
    }
    // and the premise holds across thread counts
    let short = engine::solve(
        problem.as_ref(),
        &x0,
        &spec_for("flexa", "lasso", Backend::Shared, 1, 3),
    );
    let replay = engine::solve(
        problem.as_ref(),
        &x0,
        &spec_for("flexa", "lasso", Backend::Shared, 4, 3),
    );
    assert_eq!(short.x, replay.x, "prefix determinism across thread counts");
}
