//! Integration tests for the pluggable selection subsystem
//! (`coordinator::strategy`): every strategy must (a) converge on the
//! paper's problem families and (b) be bitwise-deterministic across
//! worker-thread counts and across reruns with the same seed.

use flexa::coordinator::{
    flexa as run_flexa, gauss_jacobi, CommonOptions, FlexaOptions, GaussJacobiOptions,
    SelectionSpec, TermMetric,
};
use flexa::datagen::{logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset};
use flexa::engine::{self, SolverSpec};
use flexa::problems::{LassoProblem, LogisticProblem, NonconvexQpProblem, Problem};

/// All six strategy families of the subsystem.
fn all_specs() -> Vec<SelectionSpec> {
    vec![
        SelectionSpec::sigma(0.5),
        SelectionSpec::gauss_southwell(),
        SelectionSpec::Cyclic { frac: 0.25 },
        SelectionSpec::Random { frac: 0.25, seed: 7 },
        SelectionSpec::Importance { frac: 0.25, seed: 7 },
        SelectionSpec::Hybrid { frac: 0.25, sigma: 0.5, seed: 7 },
    ]
}

fn flexa_opts(name: String, spec: SelectionSpec, term: TermMetric, tol: f64) -> FlexaOptions {
    FlexaOptions {
        common: CommonOptions {
            max_iters: 60_000,
            max_wall_s: 120.0,
            tol,
            term,
            merit_every: 10,
            name,
            ..Default::default()
        },
        selection: spec,
        inexact: None,
    }
}

#[test]
fn every_strategy_converges_on_lasso() {
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 11));
    for spec in all_specs() {
        let o = flexa_opts(spec.name(), spec.clone(), TermMetric::RelErr, 1e-6);
        let r = run_flexa(&p, &vec![0.0; p.n()], &o);
        assert!(
            r.converged(),
            "{}: stop={:?} relerr={}",
            spec.name(),
            r.stop,
            r.final_rel_err
        );
    }
}

#[test]
fn every_strategy_converges_on_logistic() {
    // threshold matches integration_flexa's logistic stationarity test
    // (merit in gradient units; 1e-2 is its converged regime)
    let p = LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.012, 5));
    for spec in all_specs() {
        let o = flexa_opts(spec.name(), spec.clone(), TermMetric::Merit, 1e-2);
        let r = run_flexa(&p, &vec![0.0; p.n()], &o);
        assert!(
            r.final_merit <= 1e-2,
            "{}: stop={:?} merit={}",
            spec.name(),
            r.stop,
            r.final_merit
        );
    }
}

#[test]
fn every_strategy_converges_on_nonconvex_qp() {
    // the instance integration_flexa's stationarity test uses (reaches
    // merit < 1e-3 under the default options)
    let p = NonconvexQpProblem::from_instance(nonconvex_qp(60, 80, 0.1, 10.0, 100.0, 1.0, 3));
    for spec in all_specs() {
        let o = flexa_opts(spec.name(), spec.clone(), TermMetric::Merit, 1e-3);
        let r = run_flexa(&p, &vec![0.0; p.n()], &o);
        assert!(
            r.final_merit <= 1e-3,
            "{}: stop={:?} merit={}",
            spec.name(),
            r.stop,
            r.final_merit
        );
    }
}

/// The worker-pool determinism contract extends to every strategy: the
/// strategy rng lives on the calling thread and the candidate scans use
/// fixed chunk geometry, so iterates are bitwise-identical for any
/// `threads ≥ 1`.
#[test]
fn every_strategy_deterministic_across_threads() {
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 13));
    for spec in all_specs() {
        let run = |threads: usize| {
            let mut o = flexa_opts(spec.name(), spec.clone(), TermMetric::RelErr, 1e-8);
            o.common.max_iters = 400;
            o.common.tol = 0.0; // fixed work: compare identical trajectories
            o.common.threads = threads;
            run_flexa(&p, &vec![0.0; p.n()], &o)
        };
        let r1 = run(1);
        for threads in [2usize, 4] {
            let rt = run(threads);
            assert_eq!(r1.iters, rt.iters, "{} iters @ threads={threads}", spec.name());
            assert_eq!(
                r1.scanned,
                rt.scanned,
                "{} scanned @ threads={threads}",
                spec.name()
            );
            for i in 0..p.n() {
                assert!(
                    r1.x[i] == rt.x[i],
                    "{}: x[{i}] {} != {} at threads={threads}",
                    spec.name(),
                    r1.x[i],
                    rt.x[i]
                );
            }
        }
    }
}

/// Same seed ⇒ identical run; different seed ⇒ (generically) different
/// trajectory. The satellite requirement for the hybrid strategy.
#[test]
fn hybrid_rerun_reproducibility_per_seed() {
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 17));
    let run = |seed: u64| {
        let spec = SelectionSpec::Hybrid { frac: 0.25, sigma: 0.5, seed };
        let mut o = flexa_opts(spec.name(), spec, TermMetric::RelErr, 1e-8);
        o.common.max_iters = 300;
        o.common.tol = 0.0;
        run_flexa(&p, &vec![0.0; p.n()], &o)
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a.iters, b.iters);
    assert_eq!(a.scanned, b.scanned);
    assert!(a.x.iter().zip(&b.x).all(|(u, v)| u == v), "same seed diverged");
    let c = run(43);
    assert!(
        a.x.iter().zip(&c.x).any(|(u, v)| u != v),
        "different seeds produced identical iterates"
    );
}

/// The acceptance criterion of the subsystem: hybrid:0.25 reaches the
/// same objective tolerance as the greedy σ-rule while scanning at most
/// 25% of the blocks per iteration (modulo the ⌈·⌉ of the batch size).
#[test]
fn hybrid_quarter_matches_greedy_tolerance_with_quarter_scans() {
    let p = LassoProblem::from_instance(nesterov_lasso(60, 100, 0.05, 1.0, 21));
    let nb = p.blocks().n_blocks();
    let x0 = vec![0.0; p.n()];
    let tol = 1e-6;

    let greedy = run_flexa(
        &p,
        &x0,
        &flexa_opts("greedy".into(), SelectionSpec::sigma(0.5), TermMetric::RelErr, tol),
    );
    assert!(greedy.converged(), "greedy stop={:?}", greedy.stop);
    // greedy scans every block every iteration
    assert_eq!(greedy.scanned, greedy.iters * nb);

    let hybrid = run_flexa(
        &p,
        &x0,
        &flexa_opts("hybrid".into(), SelectionSpec::hybrid(0.25), TermMetric::RelErr, tol),
    );
    assert!(
        hybrid.converged(),
        "hybrid:0.25 stop={:?} relerr={}",
        hybrid.stop,
        hybrid.final_rel_err
    );
    assert!(hybrid.final_rel_err <= tol);

    // scan budget: ≤ ⌈0.25·N⌉ blocks per iteration, exactly
    let batch = ((nb as f64) * 0.25).ceil() as usize;
    assert!(
        hybrid.scanned <= hybrid.iters * batch,
        "hybrid scanned {} > {} (iters {} × batch {batch})",
        hybrid.scanned,
        hybrid.iters * batch,
        hybrid.iters
    );
}

/// GJ-with-Selection (Algorithm 3) accepts every strategy too: the
/// prepass drops to O(|C^k|) for the sketching specs.
#[test]
fn gauss_jacobi_accepts_sketching_strategies() {
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 11));
    for spec in [
        SelectionSpec::sigma(0.5),
        SelectionSpec::hybrid(0.25),
        SelectionSpec::Random { frac: 0.5, seed: 3 },
    ] {
        let o = GaussJacobiOptions {
            common: CommonOptions {
                max_iters: 20_000,
                max_wall_s: 120.0,
                tol: 1e-6,
                term: TermMetric::RelErr,
                name: format!("GJ {}", spec.name()),
                ..Default::default()
            },
            selection: Some(spec.clone()),
            processors: 4,
        };
        let r = gauss_jacobi(&p, &vec![0.0; p.n()], &o);
        assert!(
            r.converged(),
            "GJ {}: stop={:?} re={}",
            spec.name(),
            r.stop,
            r.final_rel_err
        );
    }
}

/// CDM sweeps restricted by a sketching strategy still drive the
/// objective down (essentially-cyclic coverage), and GRock runs under the
/// trait-backed Top-P selection.
#[test]
fn cdm_and_grock_route_through_the_strategy_trait() {
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 11));
    let common = CommonOptions {
        max_iters: 20_000,
        max_wall_s: 120.0,
        tol: 1e-6,
        term: TermMetric::RelErr,
        name: "cdm-cyclic".into(),
        ..Default::default()
    };
    let r = engine::solve(
        &p,
        &vec![0.0; p.n()],
        &SolverSpec::cdm_with(common.clone(), false, SelectionSpec::Cyclic { frac: 0.25 }),
    );
    assert!(r.converged(), "cdm cyclic:0.25 stop={:?} re={}", r.stop, r.final_rel_err);
    // the sketch really is a quarter-sweep
    let batch = ((p.blocks().n_blocks() as f64) * 0.25).ceil() as usize;
    assert!(r.scanned <= r.iters * batch);

    // GRock needs near-orthogonal columns (very sparse solution, more rows
    // than its P simultaneous updates can collide on) to converge — same
    // regime as the paper's §VI instance
    let pg = LassoProblem::from_instance(nesterov_lasso(80, 100, 0.02, 1.0, 7));
    let rg = engine::solve(
        &pg,
        &vec![0.0; pg.n()],
        &SolverSpec::grock_with(common, SelectionSpec::TopK { k: 4 }),
    );
    assert!(rg.converged(), "grock topk:4 stop={:?} re={}", rg.stop, rg.final_rel_err);
    for t in &rg.trace.points[1..] {
        assert!(t.active <= 4, "GRock moved {} blocks", t.active);
    }
}
