//! Integration: the three-layer path. Loads the AOT artifacts produced by
//! `make artifacts` (python/jax/pallas → HLO text), executes them through
//! PJRT, and asserts agreement with the native L3 kernels — then runs the
//! full FLEXA coordinator on the XLA engine.
//!
//! These tests are skipped (with a loud message) when artifacts are absent;
//! `make test` always builds them first. The whole file is gated behind
//! the `pjrt` feature (the XLA bindings are an external crate outside the
//! offline set).
#![cfg(feature = "pjrt")]

use flexa::coordinator::{CommonOptions, FlexaOptions, SelectionSpec, TermMetric};
use flexa::datagen::nesterov_lasso;
use flexa::problems::{LassoProblem, Problem};
use flexa::runtime::{
    flexa_with_engine, BoundXlaEngine, Manifest, NativeEngine, RuntimeClient, StepEngine,
};

fn manifest() -> Option<Manifest> {
    match Manifest::load(Manifest::default_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIPPING runtime integration ({e}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(m) = manifest() else { return };
    assert!(m.find("lasso_step", 64, 128).is_some());
    assert!(m.find("lasso_step", 512, 1024).is_some());
    assert!(m.find("logistic_step", 64, 128).is_some());
    for a in &m.artifacts {
        assert!(m.path_of(a).exists(), "{} missing on disk", a.file);
    }
}

#[test]
fn xla_engine_matches_native_engine() {
    let Some(m) = manifest() else { return };
    let client = RuntimeClient::new(m).expect("pjrt client");
    let inst = nesterov_lasso(64, 128, 0.1, 1.0, 2024);
    let problem = LassoProblem::from_instance(inst);
    let mut xla = BoundXlaEngine::new(client, &problem).expect("xla engine");
    let mut native = NativeEngine::new(&problem);

    let mut rng = flexa::rng::Xoshiro256pp::seed_from_u64(7);
    for trial in 0..5 {
        let x: Vec<f64> = (0..problem.n()).map(|_| rng.next_normal() * 0.5).collect();
        let tau = 0.5 + trial as f64;
        let (mut z1, mut e1) = (vec![0.0; 128], vec![0.0; 128]);
        let (mut z2, mut e2) = (vec![0.0; 128], vec![0.0; 128]);
        let v1 = xla.step(&x, tau, &mut z1, &mut e1).unwrap();
        let v2 = native.step(&x, tau, &mut z2, &mut e2).unwrap();
        assert!(
            (v1 - v2).abs() / v2.abs().max(1.0) < 1e-3,
            "trial {trial}: objective {v1} vs {v2}"
        );
        for i in 0..128 {
            assert!(
                (z1[i] - z2[i]).abs() < 5e-4,
                "trial {trial} z[{i}]: {} vs {}",
                z1[i],
                z2[i]
            );
            assert!((e1[i] - e2[i]).abs() < 5e-4, "trial {trial} e[{i}]");
        }
    }
}

#[test]
fn flexa_on_xla_engine_converges_end_to_end() {
    let Some(m) = manifest() else { return };
    let client = RuntimeClient::new(m).expect("pjrt client");
    let inst = nesterov_lasso(64, 128, 0.05, 1.0, 31);
    let problem = LassoProblem::from_instance(inst);
    let mut engine = BoundXlaEngine::new(client, &problem).expect("engine");
    let opts = FlexaOptions {
        common: CommonOptions {
            max_iters: 2000,
            max_wall_s: 120.0,
            tol: 1e-4, // f32 artifact: don't demand f64 accuracy
            term: TermMetric::RelErr,
            name: "FLEXA-xla".into(),
            ..Default::default()
        },
        selection: SelectionSpec::sigma(0.5),
        inexact: None,
    };
    let r = flexa_with_engine(&problem, &mut engine, &vec![0.0; problem.n()], &opts)
        .expect("engine run");
    assert!(
        r.converged(),
        "XLA-engine FLEXA: {:?} re={}",
        r.stop,
        r.final_rel_err
    );
}

#[test]
fn logistic_artifact_executes() {
    let Some(m) = manifest() else { return };
    let mut client = RuntimeClient::new(m).expect("pjrt client");
    let meta = client.find("logistic_step", 64, 128).expect("meta");
    // synthetic Ỹ and x
    let mut rng = flexa::rng::Xoshiro256pp::seed_from_u64(3);
    let mut y = vec![0.0f64; 64 * 128];
    rng.fill_normal(&mut y);
    let x = vec![0.01f64; 128];
    let inputs = vec![
        flexa::runtime::client::matrix_literal(&y, 64, 128).unwrap(),
        flexa::runtime::client::vec_literal(&x),
        flexa::runtime::client::scalar1_literal(1.0),
        flexa::runtime::client::scalar1_literal(0.25),
    ];
    let outs = client.execute(&meta, &inputs).expect("execute");
    assert_eq!(outs.len(), 3);
    let z = flexa::runtime::client::literal_to_vec(&outs[0]).unwrap();
    assert_eq!(z.len(), 128);
    assert!(z.iter().all(|v| v.is_finite()));
    // objective at x ≈ m·log2 + c‖x‖₁ for small margins
    let obj: Vec<f32> = outs[2].to_vec().unwrap();
    let expected = 64.0 * (2.0f64).ln() + 0.25 * 1.28;
    assert!(
        (obj[0] as f64 - expected).abs() / expected < 0.2,
        "objective {} vs ~{expected}",
        obj[0]
    );
}

#[test]
fn runtime_rejects_unknown_shape() {
    let Some(m) = manifest() else { return };
    let client = RuntimeClient::new(m).expect("pjrt client");
    assert!(client.find("lasso_step", 7, 9).is_err());
}
