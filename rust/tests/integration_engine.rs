//! Engine equivalence suite: every solver family routed through the one
//! `SolverCore` iteration engine must produce **bitwise-identical**
//! iterates for any worker-thread count on the paper's problem families,
//! and reruns with the same configuration (and seed, for the randomized
//! strategies) must reproduce exactly. This pins the multi-layer refactor:
//! phase composition over the shared pool is iterate-preserving, and the
//! baselines' new parallelism (fista/sparsa/admm) inherits the repo-wide
//! determinism contract. The bitwise identity against the *pre-refactor*
//! loop itself is asserted by the frozen legacy baseline in
//! `bench::engine_overhead` (unit test + `bench engine` panel).
//!
//! The **fast numerics tier** inherits the same contract: re-association
//! happens only *within* a kernel call, never across the fixed chunk
//! geometry or the ordered reductions, so fast-tier iterates must be
//! bitwise-identical across worker-thread counts too — and a fast-tier
//! run's final objective must agree with the exact tier's within the
//! documented envelope on every solver family.

use flexa::coordinator::{Backend, CommonOptions, NumericsTier, SelectionSpec, TermMetric};
use flexa::datagen::{
    dictionary_instance, logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset,
};
use flexa::engine::{self, SolverSpec};
use flexa::problems::{
    DictionaryCodesProblem, GroupLassoProblem, LassoProblem, LogisticProblem, NonconvexQpProblem,
    Problem, SvmProblem,
};
use flexa::solvers::{AdmmOptions, SparsaOptions};

fn common(name: &str, max_iters: usize, term: TermMetric) -> CommonOptions {
    CommonOptions {
        max_iters,
        max_wall_s: 120.0,
        tol: 0.0, // fixed work: compare identical trajectories
        term,
        merit_every: 10,
        name: name.into(),
        ..Default::default()
    }
}

/// Run `build(threads)` at threads ∈ {1, 2, 4} and require bitwise-equal
/// iterates, objective, iteration count, and scan accounting.
fn assert_threads_bitwise(
    problem: &dyn Problem,
    build: &dyn Fn(usize) -> SolverSpec,
    label: &str,
) {
    let x0 = vec![0.0; problem.n()];
    let r1 = engine::solve(problem, &x0, &build(1));
    assert!(
        r1.final_obj.is_finite(),
        "{label}: non-finite objective at threads=1"
    );
    for threads in [2usize, 4] {
        let rt = engine::solve(problem, &x0, &build(threads));
        assert_eq!(r1.iters, rt.iters, "{label}: iters @ threads={threads}");
        assert_eq!(r1.scanned, rt.scanned, "{label}: scanned @ threads={threads}");
        assert_eq!(
            r1.final_obj, rt.final_obj,
            "{label}: objective @ threads={threads}"
        );
        for i in 0..problem.n() {
            assert!(
                r1.x[i] == rt.x[i],
                "{label}: x[{i}] {} != {} @ threads={threads}",
                r1.x[i],
                rt.x[i]
            );
        }
    }
}

/// The engine-routed families that run on every problem kind (GRock and
/// ADMM are LASSO-regime solvers and are swept separately), with the
/// iteration budgets the bitwise sweep uses.
fn coordinator_specs(threads: usize, iters: usize, term: TermMetric) -> Vec<(String, SolverSpec)> {
    let mk = |name: &str| {
        let mut c = common(name, iters, term);
        c.threads = threads;
        c
    };
    vec![
        (
            "flexa".into(),
            SolverSpec::flexa(mk("flexa"), SelectionSpec::sigma(0.5), None),
        ),
        (
            "gauss-jacobi".into(),
            SolverSpec::gauss_jacobi(mk("gj"), None, 4),
        ),
        (
            "gj-flexa".into(),
            SolverSpec::gauss_jacobi(mk("gj-flexa"), Some(SelectionSpec::sigma(0.5)), 4),
        ),
        ("cdm".into(), SolverSpec::cdm(mk("cdm"), true)),
        ("fista".into(), SolverSpec::fista(mk("fista"))),
        (
            "sparsa".into(),
            SolverSpec::sparsa(mk("sparsa"), &SparsaOptions::default()),
        ),
    ]
}

/// [`coordinator_specs`] with every spec switched to the given numerics
/// tier.
fn coordinator_specs_tier(
    threads: usize,
    iters: usize,
    term: TermMetric,
    tier: NumericsTier,
) -> Vec<(String, SolverSpec)> {
    let mut specs = coordinator_specs(threads, iters, term);
    for (_, spec) in &mut specs {
        spec.common.numerics = tier;
    }
    specs
}

#[test]
fn engine_families_bitwise_across_threads_on_lasso() {
    let p = LassoProblem::from_instance(nesterov_lasso(50, 70, 0.1, 1.0, 17));
    for idx in 0..coordinator_specs(1, 1, TermMetric::RelErr).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 120, TermMetric::RelErr)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::RelErr)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
    // GRock and ADMM are LASSO-regime solvers: covered here
    let pg = LassoProblem::from_instance(nesterov_lasso(80, 100, 0.02, 1.0, 7));
    assert_threads_bitwise(
        &pg,
        &|threads| {
            let mut c = common("grock", 30, TermMetric::RelErr);
            c.threads = threads;
            SolverSpec::grock(c, 5)
        },
        "grock",
    );
    assert_threads_bitwise(
        &p,
        &|threads| {
            let mut c = common("admm", 80, TermMetric::RelErr);
            c.threads = threads;
            SolverSpec::admm(c, &AdmmOptions::default())
        },
        "admm",
    );
}

#[test]
fn engine_families_bitwise_across_threads_on_logistic() {
    let p = LogisticProblem::from_instance(logistic_like(LogisticPreset::Gisette, 0.012, 9));
    for idx in 0..coordinator_specs(1, 1, TermMetric::Merit).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 40, TermMetric::Merit)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::Merit)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
}

#[test]
fn engine_families_bitwise_across_threads_on_nonconvex_qp() {
    let p = NonconvexQpProblem::from_instance(nonconvex_qp(40, 60, 0.1, 10.0, 50.0, 1.0, 12));
    for idx in 0..coordinator_specs(1, 1, TermMetric::Merit).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 60, TermMetric::Merit)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::Merit)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
}

#[test]
fn engine_families_bitwise_across_threads_on_group_lasso() {
    let p = GroupLassoProblem::from_instance(nesterov_lasso(30, 48, 0.1, 1.0, 14), 4);
    for idx in 0..coordinator_specs(1, 1, TermMetric::Merit).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 40, TermMetric::Merit)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::Merit)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
}

#[test]
fn engine_families_bitwise_across_threads_on_svm() {
    let inst = logistic_like(LogisticPreset::Gisette, 0.012, 15);
    let p = SvmProblem::new(inst.y, &inst.labels, inst.c.max(0.1));
    for idx in 0..coordinator_specs(1, 1, TermMetric::Merit).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 40, TermMetric::Merit)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::Merit)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
}

#[test]
fn engine_families_bitwise_across_threads_on_dictionary_codes() {
    let p = DictionaryCodesProblem::from_instance(&dictionary_instance(10, 6, 10, 0.3, 0.01, 16));
    for idx in 0..coordinator_specs(1, 1, TermMetric::Merit).len() {
        let build = |threads: usize| {
            coordinator_specs(threads, 40, TermMetric::Merit)[idx].1.clone()
        };
        let label = coordinator_specs(1, 1, TermMetric::Merit)[idx].0.clone();
        assert_threads_bitwise(&p, &build, &label);
    }
}

#[test]
fn sharded_backend_bitwise_on_all_six_families() {
    // the backend axis of the coverage matrix: shared ≡ sharded for a
    // scan solver (flexa) and the sequential sweep (cdm) on every
    // problem family, at threads {1, 2, 4} each
    let problems: Vec<(&str, Box<dyn Problem>)> = vec![
        ("lasso", Box::new(LassoProblem::from_instance(nesterov_lasso(30, 40, 0.1, 1.0, 44)))),
        (
            "group-lasso",
            Box::new(GroupLassoProblem::from_instance(nesterov_lasso(30, 40, 0.1, 1.0, 44), 4)),
        ),
        (
            "logistic",
            Box::new(LogisticProblem::from_instance(logistic_like(
                LogisticPreset::Gisette,
                0.01,
                44,
            ))),
        ),
        ("svm", {
            let inst = logistic_like(LogisticPreset::Gisette, 0.01, 45);
            Box::new(SvmProblem::new(inst.y, &inst.labels, inst.c.max(0.1)))
        }),
        (
            "nonconvex-qp",
            Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
                30, 40, 0.1, 10.0, 50.0, 1.0, 44,
            ))),
        ),
        (
            "dictionary",
            Box::new(DictionaryCodesProblem::from_instance(&dictionary_instance(
                8, 5, 9, 0.3, 0.01, 44,
            ))),
        ),
    ];
    for (kind, p) in &problems {
        assert!(p.supports_column_shard(), "{kind}: no sharded path");
        let x0 = vec![0.0; p.n()];
        for solver in ["flexa", "cdm"] {
            let run = |backend: Backend, threads: usize| {
                let mut c = common(solver, 25, TermMetric::Merit);
                c.threads = threads;
                c.cores = 4;
                c.backend = backend;
                let spec = SolverSpec::from_name(solver, c, None, 0.5, 4)
                    .unwrap_or_else(|e| panic!("{kind}/{solver}: {e}"));
                engine::solve(p.as_ref(), &x0, &spec)
            };
            let reference = run(Backend::Shared, 1);
            for threads in [1usize, 2, 4] {
                let sharded = run(Backend::Sharded, threads);
                assert_eq!(
                    reference.x, sharded.x,
                    "{kind}/{solver}: sharded diverged at threads={threads}"
                );
                assert_eq!(reference.final_obj, sharded.final_obj, "{kind}/{solver}");
                assert!(
                    !sharded.comm.is_empty(),
                    "{kind}/{solver}: sharded run measured no communication"
                );
            }
        }
    }
}

#[test]
fn fast_tier_is_bitwise_across_threads_on_core_problems() {
    // the fast tier re-associates only within a kernel call; the chunk
    // geometry and ordered reductions are untouched, so its iterates are
    // just as thread-invariant as the exact tier's
    let problems: Vec<(&'static str, Box<dyn Problem>, TermMetric, usize)> = vec![
        (
            "lasso",
            Box::new(LassoProblem::from_instance(nesterov_lasso(50, 70, 0.1, 1.0, 17))),
            TermMetric::RelErr,
            60,
        ),
        (
            "logistic",
            Box::new(LogisticProblem::from_instance(logistic_like(
                LogisticPreset::Gisette,
                0.012,
                9,
            ))),
            TermMetric::Merit,
            30,
        ),
        (
            "nonconvex-qp",
            Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
                40, 60, 0.1, 10.0, 50.0, 1.0, 12,
            ))),
            TermMetric::Merit,
            30,
        ),
    ];
    for (kind, p, term, iters) in &problems {
        for idx in 0..coordinator_specs(1, 1, *term).len() {
            let build = |threads: usize| {
                coordinator_specs_tier(threads, *iters, *term, NumericsTier::Fast)[idx].1.clone()
            };
            let label = format!("{kind}/{} fast-tier", coordinator_specs(1, 1, *term)[idx].0);
            assert_threads_bitwise(p.as_ref(), &build, &label);
        }
    }
}

#[test]
fn fast_tier_objective_agrees_with_exact_across_families() {
    // end-to-end consequence of the kernel envelope: after a fixed
    // iteration budget, the fast tier's objective lands within a
    // documented relative tolerance of the exact tier's on every
    // engine-routed family
    const TOL: f64 = 1e-6;
    let problems: Vec<(&'static str, Box<dyn Problem>, TermMetric, usize)> = vec![
        (
            "lasso",
            Box::new(LassoProblem::from_instance(nesterov_lasso(50, 70, 0.1, 1.0, 17))),
            TermMetric::RelErr,
            60,
        ),
        (
            "logistic",
            Box::new(LogisticProblem::from_instance(logistic_like(
                LogisticPreset::Gisette,
                0.012,
                9,
            ))),
            TermMetric::Merit,
            30,
        ),
        (
            "nonconvex-qp",
            Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
                40, 60, 0.1, 10.0, 50.0, 1.0, 12,
            ))),
            TermMetric::Merit,
            30,
        ),
    ];
    for (kind, p, term, iters) in &problems {
        let x0 = vec![0.0; p.n()];
        let n_specs = coordinator_specs(1, 1, *term).len();
        for idx in 0..n_specs {
            let exact = engine::solve(
                p.as_ref(),
                &x0,
                &coordinator_specs_tier(1, *iters, *term, NumericsTier::Exact)[idx].1,
            );
            let fast = engine::solve(
                p.as_ref(),
                &x0,
                &coordinator_specs_tier(1, *iters, *term, NumericsTier::Fast)[idx].1,
            );
            let label = &coordinator_specs(1, 1, *term)[idx].0;
            assert!(exact.final_obj.is_finite(), "{kind}/{label}: exact objective");
            assert!(fast.final_obj.is_finite(), "{kind}/{label}: fast objective");
            let scale = exact.final_obj.abs().max(1.0);
            assert!(
                (exact.final_obj - fast.final_obj).abs() <= TOL * scale,
                "{kind}/{label}: fast-tier objective {:e} drifted from exact {:e} \
                 past rel tol {TOL:e}",
                fast.final_obj,
                exact.final_obj
            );
        }
    }
}

#[test]
fn newly_parallel_fista_and_sparsa_reproduce_per_run() {
    // seed/rerun reproducibility for the baselines the engine made
    // pool-parallel: identical configs ⇒ identical trajectories
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 23));
    let x0 = vec![0.0; p.n()];
    for (label, spec) in [
        (
            "fista",
            SolverSpec::fista(common("fista", 80, TermMetric::RelErr)),
        ),
        (
            "sparsa",
            SolverSpec::sparsa(
                common("sparsa", 80, TermMetric::RelErr),
                &SparsaOptions::default(),
            ),
        ),
    ] {
        let a = engine::solve(&p, &x0, &spec);
        let b = engine::solve(&p, &x0, &spec);
        assert_eq!(a.iters, b.iters, "{label}");
        assert!(a.x.iter().zip(&b.x).all(|(u, v)| u == v), "{label}: rerun diverged");
    }
}

#[test]
fn sketched_fista_is_seed_reproducible_and_seed_sensitive() {
    // the selection axis fista gained: same seed ⇒ identical run,
    // different seed ⇒ (generically) different trajectory
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 29));
    let x0 = vec![0.0; p.n()];
    let run = |seed: u64| {
        let spec = SolverSpec::fista(common("fista-hybrid", 60, TermMetric::RelErr))
            .with_selection(SelectionSpec::Hybrid { frac: 0.5, sigma: 0.5, seed });
        engine::solve(&p, &x0, &spec)
    };
    let a = run(42);
    let b = run(42);
    assert_eq!(a.scanned, b.scanned);
    assert!(a.x.iter().zip(&b.x).all(|(u, v)| u == v), "same seed diverged");
    let c = run(43);
    assert!(
        a.x.iter().zip(&c.x).any(|(u, v)| u != v),
        "different seeds produced identical iterates"
    );
}

#[test]
fn baselines_account_scans_through_the_engine() {
    // scanned was previously only tracked by the coordinator loops; the
    // engine accounts it for every family
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 31));
    let x0 = vec![0.0; p.n()];
    let nb = p.blocks().n_blocks();
    for (label, spec) in [
        ("fista", SolverSpec::fista(common("fista", 30, TermMetric::RelErr))),
        (
            "sparsa",
            SolverSpec::sparsa(common("sparsa", 30, TermMetric::RelErr), &SparsaOptions::default()),
        ),
        (
            "admm",
            SolverSpec::admm(common("admm", 30, TermMetric::RelErr), &AdmmOptions::default()),
        ),
    ] {
        let r = engine::solve(&p, &x0, &spec);
        assert_eq!(r.scanned, r.iters * nb, "{label}: full-vector scan accounting");
    }
}

#[test]
fn engine_equivalence_matches_classic_solver_wrappers() {
    // the thin public wrappers must be pure aliases of the engine specs
    let p = LassoProblem::from_instance(nesterov_lasso(40, 60, 0.1, 1.0, 37));
    let x0 = vec![0.0; p.n()];
    let c = common("wrap", 60, TermMetric::RelErr);

    let via_wrapper = flexa::solvers::fista(&p, &x0, &c);
    let via_engine = engine::solve(&p, &x0, &SolverSpec::fista(c.clone()));
    assert_eq!(via_wrapper.x, via_engine.x);

    let via_wrapper = flexa::coordinator::flexa(
        &p,
        &x0,
        &flexa::coordinator::FlexaOptions {
            common: c.clone(),
            selection: SelectionSpec::sigma(0.5),
            inexact: None,
        },
    );
    let via_engine =
        engine::solve(&p, &x0, &SolverSpec::flexa(c, SelectionSpec::sigma(0.5), None));
    assert_eq!(via_wrapper.x, via_engine.x);
}
