//! Property-based tests (randomized, seeded, shrink-free mini-proptest):
//! the paper's structural invariants checked across hundreds of random
//! instances rather than hand-picked examples.

use flexa::coordinator::{
    Backend, CommonOptions, Schedule, SelectionRule, SelectionSpec, StepRule, TermMetric,
};
use flexa::datagen::{
    dictionary_instance, logistic_like, nesterov_lasso, nonconvex_qp, LogisticPreset,
};
use flexa::engine::{self, DepGraph, DirectionRule, MergeRule, SolverSpec};
use flexa::io::libsvm::{load_libsvm, write_libsvm};
use flexa::io::matrix_market::{load_matrix_market, write_matrix_market};
use flexa::io::store::MmapCscStore;
use flexa::linalg::{vector, BlockPartition, CscMatrix, DenseMatrix, Matrix};
use flexa::metrics::IterCost;
use flexa::parallel::{allreduce_sum, row_chunks, ShardLayout, WorkerPool};
use flexa::problems::{
    DictionaryCodesProblem, GroupLassoProblem, LassoProblem, LogisticProblem, NonconvexQpProblem,
    Problem, SvmProblem,
};
use flexa::rng::Xoshiro256pp;
use flexa::simulator::CostModel;
use flexa::util::Json;

/// Run `f` across `cases` seeded cases; panics carry the seed for replay.
fn for_all(cases: usize, mut f: impl FnMut(&mut Xoshiro256pp)) {
    for seed in 0..cases as u64 {
        let mut rng = Xoshiro256pp::seed_from_u64(0xFEED_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[test]
fn prop_soft_threshold_is_prox() {
    // u = ST(v,t) minimizes ½(u−v)² + t|u| ⇔ v−u ∈ t∂|u|
    for_all(300, |rng| {
        let v = rng.uniform(-10.0, 10.0);
        let t = rng.uniform(1e-6, 5.0);
        let u = vector::soft_threshold(v, t);
        if u != 0.0 {
            assert!(((v - u) - t * u.signum()).abs() < 1e-10);
            assert!(u.signum() == v.signum());
            assert!(u.abs() <= v.abs());
        } else {
            assert!(v.abs() <= t + 1e-12);
        }
    });
}

#[test]
fn prop_block_soft_threshold_shrinks() {
    for_all(200, |rng| {
        let n = 1 + rng.next_usize(8);
        let v: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let t = rng.uniform(1e-6, 3.0);
        let mut out = vec![0.0; n];
        vector::block_soft_threshold(&v, t, &mut out);
        let nv = vector::nrm2(&v);
        let no = vector::nrm2(&out);
        assert!(no <= nv + 1e-12);
        if nv > t {
            assert!((no - (nv - t)).abs() < 1e-9, "norm shrinks by exactly t");
        } else {
            assert_eq!(no, 0.0);
        }
    });
}

#[test]
fn prop_sparse_equals_dense() {
    for_all(60, |rng| {
        let m = 1 + rng.next_usize(20);
        let n = 1 + rng.next_usize(20);
        let mut triplets = Vec::new();
        let mut dense = DenseMatrix::zeros(m, n);
        for _ in 0..rng.next_usize(m * n + 1) {
            let (i, j, v) = (rng.next_usize(m), rng.next_usize(n), rng.next_normal());
            triplets.push((i, j, v));
            dense.set(i, j, dense.get(i, j) + v);
        }
        let sparse = CscMatrix::from_triplets(m, n, &triplets);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.next_normal()).collect();
        let (mut od, mut os) = (vec![0.0; m], vec![0.0; m]);
        dense.matvec(&x, &mut od);
        sparse.matvec(&x, &mut os);
        assert!(vector::dist2(&od, &os) < 1e-9);
        let (mut td, mut ts) = (vec![0.0; n], vec![0.0; n]);
        dense.matvec_t(&y, &mut td);
        sparse.matvec_t(&y, &mut ts);
        assert!(vector::dist2(&td, &ts) < 1e-9);
        for j in 0..n {
            assert!((dense.col_dot(j, &y) - sparse.col_dot(j, &y)).abs() < 1e-10);
            assert!(
                (dense.col_sq_weighted_dot(j, &y) - sparse.col_sq_weighted_dot(j, &y)).abs()
                    < 1e-9
            );
        }
    });
}

#[test]
fn prop_selection_contains_argmax_and_respects_sigma() {
    for_all(200, |rng| {
        let n = 1 + rng.next_usize(50);
        let e: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let sigma = rng.next_f64();
        let rule = SelectionRule::sigma(sigma);
        let mut sel = Vec::new();
        let m = rule.select(&e, &mut sel);
        assert!(!sel.is_empty());
        let argmax = (0..n).max_by(|&a, &b| e[a].partial_cmp(&e[b]).unwrap()).unwrap();
        assert!((m - e[argmax]).abs() < 1e-15);
        assert!(sel.contains(&argmax), "argmax must always be selected");
        for &i in &sel {
            if sigma > 0.0 && m > 0.0 {
                assert!(e[i] >= sigma * m - 1e-15, "selected below threshold");
            }
        }
        // everything above threshold is selected (no false negatives)
        if sigma > 0.0 && m > 0.0 {
            for i in 0..n {
                if e[i] >= sigma * m {
                    assert!(sel.contains(&i));
                }
            }
        }
    });
}

#[test]
fn prop_descent_inequality_17() {
    // Prop. 8(c): (x̂−x)_Sᵀ∇F + Σ_S g(x̂_i) − g(x_i) ≤ −c_τ ‖(x̂−x)_S‖²
    for_all(40, |rng| {
        let m = 10 + rng.next_usize(20);
        let n = 10 + rng.next_usize(30);
        let inst = nesterov_lasso(m, n, 0.2, 1.0, rng.next_u64());
        let p = LassoProblem::from_instance(inst);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal() * 0.5).collect();
        let mut aux = vec![0.0; m];
        p.init_aux(&x, &mut aux);
        let tau = rng.uniform(0.1, 5.0);
        let mut grad = vec![0.0; n];
        p.grad_full(&x, &aux, &mut grad);
        let mut lhs = 0.0;
        let mut dist_sq = 0.0;
        let mut z = [0.0];
        for i in 0..n {
            p.best_response(i, &x, &aux, tau, &mut z);
            let d = z[0] - x[i];
            lhs += d * grad[i] + p.c() * (z[0].abs() - x[i].abs());
            dist_sq += d * d;
        }
        // c_τ = q·min τ_i; with Q = I and the exact quadratic the modulus
        // is at least τ/2 — use the safe constant τ/2
        assert!(
            lhs <= -0.5 * tau * dist_sq + 1e-9,
            "descent inequality violated: lhs={lhs}, bound={}",
            -0.5 * tau * dist_sq
        );
    });
}

#[test]
fn prop_fixed_point_iff_stationary() {
    // Prop. 8(b) on generator instances: x* is a fixed point of x̂(·)
    for_all(25, |rng| {
        let m = 15 + rng.next_usize(15);
        let n = 20 + rng.next_usize(20);
        let inst = nesterov_lasso(m, n, 0.15, 1.0, rng.next_u64());
        let x_star = inst.x_star.clone();
        let p = LassoProblem::from_instance(inst);
        let mut aux = vec![0.0; m];
        p.init_aux(&x_star, &mut aux);
        let tau = rng.uniform(0.1, 10.0);
        let mut z = [0.0];
        for i in 0..n {
            let e = p.best_response(i, &x_star, &aux, tau, &mut z);
            assert!(e < 1e-8, "x* not a fixed point at block {i}: E={e}");
        }
        // and a random non-stationary point is NOT a fixed point
        let mut y = x_star.clone();
        y[rng.next_usize(n)] += 1.0;
        p.init_aux(&y, &mut aux);
        let total: f64 = (0..n)
            .map(|i| p.best_response(i, &y, &aux, tau, &mut z))
            .sum();
        assert!(total > 1e-6, "perturbed point behaves like a fixed point");
    });
}

#[test]
fn prop_simulator_monotone() {
    for_all(200, |rng| {
        let model = CostModel::default();
        let flops = rng.uniform(1e3, 1e12);
        let words = rng.uniform(0.0, 1e6);
        let p1 = 1 + rng.next_usize(64);
        let p2 = p1 + 1 + rng.next_usize(64);
        // balanced work ⇒ more cores never slower on the compute term
        let c1 = IterCost::balanced(flops, p1, words, 1.0);
        let c2 = IterCost::balanced(flops, p2, words, 1.0);
        let t1 = model.iter_time_s(&c1, p1);
        let t2 = model.iter_time_s(&c2, p2);
        // compute part shrinks; comm may grow — total can cross over only
        // when comm dominates. Assert the compute-only ordering:
        let comp1 = c1.flops_max_worker / (model.core_gflops * 1e9);
        let comp2 = c2.flops_max_worker / (model.core_gflops * 1e9);
        assert!(comp2 <= comp1 + 1e-15);
        // and the full model stays finite/positive
        assert!(t1 > 0.0 && t2 > 0.0 && t1.is_finite() && t2.is_finite());
    });
}

#[test]
fn prop_partition_covers_exactly() {
    for_all(200, |rng| {
        let n = 1 + rng.next_usize(200);
        let p = match rng.next_usize(3) {
            0 => BlockPartition::scalar(n),
            1 => BlockPartition::uniform(n, 1 + rng.next_usize(n)),
            _ => BlockPartition::by_count(n, 1 + rng.next_usize(n)),
        };
        assert_eq!(p.dim(), n);
        let mut covered = vec![false; n];
        for i in 0..p.n_blocks() {
            for v in p.range(i) {
                assert!(!covered[v], "index {v} covered twice");
                covered[v] = true;
            }
        }
        assert!(covered.iter().all(|&c| c), "some index uncovered");
        // block_of agrees with ranges
        for v in (0..n).step_by(1 + n / 13) {
            assert!(p.range(p.block_of(v)).contains(&v));
        }
    });
}

#[test]
fn prop_json_roundtrip() {
    fn random_json(rng: &mut Xoshiro256pp, depth: usize) -> Json {
        match if depth > 2 { rng.next_usize(4) } else { rng.next_usize(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.next_f64() < 0.5),
            2 => Json::Num((rng.next_normal() * 100.0 * 64.0).round() / 64.0),
            3 => Json::Str(
                (0..rng.next_usize(12))
                    .map(|_| char::from(b'a' + rng.next_usize(26) as u8))
                    .collect(),
            ),
            4 => Json::Arr((0..rng.next_usize(4)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.next_usize(4))
                    .map(|k| (format!("k{k}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for_all(300, |rng| {
        let j = random_json(rng, 0);
        let s = j.to_string_compact();
        let back = Json::parse(&s).unwrap_or_else(|e| panic!("parse {s:?}: {e}"));
        assert_eq!(j, back, "roundtrip failed for {s}");
    });
}

#[test]
fn prop_incremental_residual_never_drifts() {
    // failure-injection flavored: long random walks of block updates keep
    // the incremental residual within f64 drift bounds of a fresh recompute
    for_all(20, |rng| {
        let m = 20 + rng.next_usize(20);
        let n = 20 + rng.next_usize(40);
        let inst = nesterov_lasso(m, n, 0.3, 1.0, rng.next_u64());
        let p = LassoProblem::from_instance(inst);
        let mut x = vec![0.0; n];
        let mut aux = vec![0.0; m];
        p.init_aux(&x, &mut aux);
        for _ in 0..500 {
            let i = rng.next_usize(n);
            let d = rng.next_normal();
            x[i] += d;
            p.apply_block_delta(i, &[d], &mut aux);
        }
        let mut fresh = vec![0.0; m];
        p.init_aux(&x, &mut fresh);
        let drift = vector::dist2(&aux, &fresh) / vector::nrm2(&fresh).max(1.0);
        assert!(drift < 1e-9, "relative drift {drift}");
    });
}

#[test]
fn prop_sharded_allreduce_matches_sequential_fixed_order_sum_bitwise() {
    // the deterministic in-process allreduce behind `--backend sharded`:
    // out = Σ_s partials[s] in ascending shard order per element, for ANY
    // worker-thread count — bit-for-bit, not within tolerance
    for_all(60, |rng| {
        let shards = 1 + rng.next_usize(7);
        let m = 1 + rng.next_usize(300);
        let partials: Vec<Vec<f64>> = (0..shards)
            .map(|_| {
                (0..m)
                    .map(|_| rng.next_normal() * 10f64.powi(rng.next_usize(7) as i32 - 3))
                    .collect()
            })
            .collect();
        let chunks = row_chunks(m);
        // the sequential fixed-order fold is the specification
        let mut expect = vec![0.0f64; m];
        for p in &partials {
            for (o, v) in expect.iter_mut().zip(p) {
                *o += *v;
            }
        }
        for threads in [1usize, 2, 4, 8] {
            let pool = WorkerPool::new(threads);
            let mut out = vec![f64::NAN; m];
            allreduce_sum(&pool, &partials, &mut out, &chunks);
            for j in 0..m {
                assert!(
                    out[j].to_bits() == expect[j].to_bits(),
                    "threads={threads} j={j}: {:016x} != {:016x}",
                    out[j].to_bits(),
                    expect[j].to_bits()
                );
            }
        }
    });
}

#[test]
fn prop_shard_layout_partitions_blocks_and_columns_exactly_once() {
    // owner-computes soundness: every block (and column) belongs to
    // exactly one shard, shards are contiguous and ascending, and the
    // boundaries depend only on (N, S)
    for_all(120, |rng| {
        let n = 1 + rng.next_usize(200);
        let shards = 1 + rng.next_usize(12);
        let blocks = if rng.next_f64() < 0.5 {
            BlockPartition::scalar(n)
        } else {
            BlockPartition::uniform(n, 1 + rng.next_usize(7))
        };
        let nb = blocks.n_blocks();
        let layout = ShardLayout::contiguous(&blocks, shards);
        assert_eq!(layout.n_shards(), shards);
        let mut block_owner = vec![usize::MAX; nb];
        let mut col_owner = vec![usize::MAX; blocks.dim()];
        let mut prev_end = 0usize;
        for s in 0..shards {
            let br = layout.block_range(s);
            assert_eq!(br.start, prev_end, "shard block ranges must be contiguous");
            prev_end = br.end;
            for i in br.clone() {
                assert_eq!(block_owner[i], usize::MAX, "block {i} owned twice");
                block_owner[i] = s;
                assert_eq!(layout.owner(i), s);
            }
            let cr = layout.col_range(s);
            for j in cr {
                assert_eq!(col_owner[j], usize::MAX, "column {j} owned twice");
                col_owner[j] = s;
            }
        }
        assert_eq!(prev_end, nb, "blocks not covered");
        assert!(block_owner.iter().all(|&s| s != usize::MAX));
        assert!(col_owner.iter().all(|&s| s != usize::MAX), "columns not covered");
        // same (N, S) ⇒ same boundaries (thread/seed independent)
        let again = ShardLayout::contiguous(&blocks, shards);
        for s in 0..shards {
            assert_eq!(layout.block_range(s), again.block_range(s));
            assert_eq!(layout.col_range(s), again.col_range(s));
        }
    });
}

/// Random sparse matrix (plus ±1 labels) with a guaranteed entry in the
/// last column, so text formats that infer dims can reconstruct them.
fn random_csc_with_labels(rng: &mut Xoshiro256pp) -> (CscMatrix, Vec<f64>) {
    let m = 1 + rng.next_usize(16);
    let n = 1 + rng.next_usize(16);
    let mut triplets = vec![(rng.next_usize(m), n - 1, 1.0 + rng.next_f64())];
    for _ in 0..rng.next_usize(3 * (m + n) + 1) {
        triplets.push((rng.next_usize(m), rng.next_usize(n), rng.next_normal()));
    }
    let labels: Vec<f64> = (0..m).map(|_| if rng.next_f64() < 0.5 { -1.0 } else { 1.0 }).collect();
    (CscMatrix::from_triplets(m, n, &triplets), labels)
}

/// Structural + bitwise value equality between two CSC matrices.
fn assert_csc_bitwise_eq(tag: &str, a: &CscMatrix, b: &CscMatrix) {
    assert_eq!((a.nrows(), a.ncols(), a.nnz()), (b.nrows(), b.ncols(), b.nnz()), "{tag}: dims");
    for j in 0..a.ncols() {
        let (ra, va) = a.col(j);
        let (rb, vb) = b.col(j);
        assert_eq!(ra, rb, "{tag}: rowind of column {j}");
        for (x, y) in va.iter().zip(vb) {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}: value bits in column {j}");
        }
    }
}

#[test]
fn prop_loader_round_trips_are_bitwise() {
    // write → reload must be the identity, bit-for-bit, for every format:
    // the writers use Rust's shortest round-trip f64 formatting (text) or
    // raw little-endian bytes (store), so nothing may drift
    let dir = std::env::temp_dir().join(format!("flexa_prop_io_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    for_all(24, |rng| {
        let (a, labels) = random_csc_with_labels(rng);
        let tag = rng.next_u64();

        let svm = dir.join(format!("rt_{tag:016x}.libsvm"));
        write_libsvm(&svm, &a, &labels).unwrap();
        let (back, lb) = load_libsvm(&svm).unwrap();
        assert_csc_bitwise_eq("libsvm", &a, &back);
        assert_eq!(labels.len(), lb.len(), "libsvm label count");
        for (x, y) in labels.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits(), "libsvm label bits");
        }

        let mtx = dir.join(format!("rt_{tag:016x}.mtx"));
        write_matrix_market(&mtx, &a).unwrap();
        let back = load_matrix_market(&mtx).unwrap();
        assert_csc_bitwise_eq("matrix-market", &a, &back);

        let store = dir.join(format!("rt_{tag:016x}.fxm"));
        MmapCscStore::write(&store, &a, Some(&labels)).unwrap();
        let s = MmapCscStore::open(&store).unwrap();
        assert_csc_bitwise_eq("flexa-mmap", &a, &s.matrix);
        let lb = s.labels.expect("labels must round-trip through the store");
        for (x, y) in labels.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits(), "store label bits");
        }
    });
}

/// One small instance of every `Problem` family, seeded.
fn all_family_problems(seed: u64) -> Vec<(&'static str, Box<dyn Problem>)> {
    let log_inst = logistic_like(LogisticPreset::Gisette, 0.01, seed);
    let svm_inst = logistic_like(LogisticPreset::Gisette, 0.01, seed + 1);
    // Seventh family: a lasso whose matrix round-trips through an
    // on-disk flexa-mmap store, so the shard-view contract below runs
    // against mapped (zero-copy) column storage too.
    let mut mrng = Xoshiro256pp::seed_from_u64(seed ^ 0x10_CA11);
    let (m, n) = (18, 26);
    let mut triplets = vec![(m - 1, n - 1, mrng.next_normal())];
    for _ in 0..3 * (m + n) {
        triplets.push((mrng.next_usize(m), mrng.next_usize(n), mrng.next_normal()));
    }
    let a = CscMatrix::from_triplets(m, n, &triplets);
    let b: Vec<f64> = (0..m).map(|_| mrng.next_normal()).collect();
    let dir = std::env::temp_dir()
        .join(format!("flexa_prop_family_{}_{seed:016x}.fxm", std::process::id()));
    MmapCscStore::write(&dir, &a, Some(&b)).expect("write family mmap store");
    let store = MmapCscStore::open(&dir).expect("open family mmap store");
    let b = store.labels.clone().expect("family store labels");
    vec![
        (
            "lasso",
            Box::new(LassoProblem::from_instance(nesterov_lasso(20, 30, 0.2, 1.0, seed)))
                as Box<dyn Problem>,
        ),
        (
            "group-lasso",
            Box::new(GroupLassoProblem::from_instance(
                nesterov_lasso(20, 24, 0.2, 1.0, seed),
                4,
            )),
        ),
        ("logistic", Box::new(LogisticProblem::from_instance(log_inst))),
        (
            "svm",
            Box::new(SvmProblem::new(svm_inst.y, &svm_inst.labels, svm_inst.c.max(0.1))),
        ),
        (
            "nonconvex-qp",
            Box::new(NonconvexQpProblem::from_instance(nonconvex_qp(
                20, 30, 0.2, 10.0, 50.0, 1.0, seed,
            ))),
        ),
        (
            "dictionary",
            Box::new(DictionaryCodesProblem::from_instance(&dictionary_instance(
                8,
                5,
                9,
                0.4,
                0.01,
                seed,
            ))),
        ),
        ("lasso-mmap", Box::new(LassoProblem::new(Matrix::Sparse(store.matrix), b, 0.3, None))),
    ]
}

#[test]
fn prop_every_family_shards_and_shard_views_match_full_problem_bitwise() {
    // the generic owner-computes contract: for EVERY Problem impl that
    // exposes column_shard (all families incl. the mmap-backed lasso —
    // future ones are picked up through all_family_problems), a shard's
    // best-response / scratch-assisted best-response / delta application
    // over a random block range must equal the full-matrix methods
    // bit-for-bit, which is the entire backend-equivalence argument
    for_all(8, |rng| {
        for (name, problem) in &all_family_problems(rng.next_u64()) {
            let problem = problem.as_ref();
            assert!(problem.supports_column_shard(), "{name}: no column-shard view");
            let nb = problem.blocks().n_blocks();
            let lo = rng.next_usize(nb);
            let hi = (lo + 1 + rng.next_usize(nb - lo)).min(nb);
            let shard = problem.column_shard(lo..hi).expect("probe said shards exist");
            assert_eq!(shard.block_range(), lo..hi, "{name}");

            let x: Vec<f64> = (0..problem.n()).map(|_| rng.next_normal() * 0.4).collect();
            let mut aux = vec![0.0; problem.aux_len()];
            problem.init_aux(&x, &mut aux);
            let mut scratch = vec![0.0; problem.prelude_len()];
            problem.prelude(&x, &aux, &mut scratch);
            // ≥ tau_min keeps the nonconvex QP's subproblems well-posed
            let tau = problem.tau_init().max(problem.tau_min());

            let mb = problem.blocks().max_size();
            let (mut zf, mut zs) = (vec![0.0; mb], vec![0.0; mb]);
            for i in lo..hi {
                let bl = problem.blocks().range(i).len();
                let ef = problem.best_response(i, &x, &aux, tau, &mut zf[..bl]);
                let es = shard.best_response(i, &x, &aux, tau, &mut zs[..bl]);
                assert_eq!(ef.to_bits(), es.to_bits(), "{name}: E_{i}");
                assert_eq!(&zf[..bl], &zs[..bl], "{name}: zhat block {i}");
                let ef = problem.best_response_with(i, &x, &aux, &scratch, tau, &mut zf[..bl]);
                let es = shard.best_response_with(i, &x, &aux, &scratch, tau, &mut zs[..bl]);
                assert_eq!(ef.to_bits(), es.to_bits(), "{name}: scratch E_{i}");
                assert_eq!(&zf[..bl], &zs[..bl], "{name}: scratch zhat block {i}");

                let delta: Vec<f64> = (0..bl).map(|_| rng.next_normal() * 0.3).collect();
                let mut af = aux.clone();
                let mut as_ = aux.clone();
                problem.apply_block_delta(i, &delta, &mut af);
                shard.apply_block_delta(i, &delta, &mut as_);
                for j in 0..af.len() {
                    assert_eq!(
                        af[j].to_bits(),
                        as_[j].to_bits(),
                        "{name}: delta image row {j} of block {i}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_depgraph_coloring_is_conflict_free_and_matches_overlap() {
    // the scheduling soundness invariant behind `--schedule dag`: the
    // dependency graph's adjacency is EXACTLY row-support overlap (no
    // missed conflict, no phantom edge), conflicting blocks never share
    // a color (epoch), and the palette is compact
    for_all(60, |rng| {
        let m = 4 + rng.next_usize(30);
        let n = 4 + rng.next_usize(30);
        let mut triplets = Vec::new();
        for j in 0..n {
            for _ in 0..(1 + rng.next_usize(3)) {
                triplets.push((rng.next_usize(m), j, rng.next_normal()));
            }
        }
        let a = Matrix::Sparse(CscMatrix::from_triplets(m, n, &triplets));
        let b: Vec<f64> = (0..m).map(|_| rng.next_normal()).collect();
        let p = LassoProblem::new(a, b, 0.1, None);
        let g = DepGraph::build(&p);
        assert!(!g.dense, "CSC instance must color sparsely");
        assert_eq!(g.n_blocks(), n);
        g.validate().unwrap();

        // ground truth recomputed independently from the locality
        // contract: blocks couple iff their aux row supports intersect
        let supports: Vec<Vec<usize>> =
            (0..n).map(|i| p.block_rows(i).expect("sparse columns report rows")).collect();
        for i in 0..n {
            for j in 0..n {
                let overlap = i != j
                    && supports[i].iter().any(|r| supports[j].binary_search(r).is_ok());
                assert_eq!(g.adjacent(i, j), overlap, "adjacency mismatch at ({i},{j})");
                if overlap {
                    assert_ne!(
                        g.color[i], g.color[j],
                        "structurally conflicting blocks {i},{j} share an epoch"
                    );
                }
            }
        }
        // greedy coloring leaves no gap in the palette
        let mut used = vec![false; g.n_colors];
        for &c in &g.color {
            used[c] = true;
        }
        assert!(used.iter().all(|&u| u), "gap in the color palette");
    });
}

/// A FLEXA spec for the random-schedule sweep: fixed γ and pinned τ so
/// the dag arm is deterministic, with the caller's σ and staleness.
fn random_dag_spec(
    schedule: Schedule,
    threads: usize,
    backend: Backend,
    sigma: f64,
) -> SolverSpec {
    SolverSpec {
        common: CommonOptions {
            max_iters: 10,
            tol: 0.0,
            term: TermMetric::Merit,
            cores: 4,
            threads,
            backend,
            schedule,
            stepsize: StepRule::Constant { gamma: 0.5 },
            name: "prop-dag".into(),
            ..Default::default()
        },
        direction: DirectionRule::BestResponse { tau0: Some(0.3) },
        merge: MergeRule::Jacobi { full_step: false },
        selection: Some(SelectionSpec::sigma(sigma)),
        inexact: None,
    }
}

#[test]
fn prop_random_dag_schedules_stay_bitwise_across_backends_and_threads() {
    // the eager per-color exchange of the sharded communication plane is
    // an accounting/overlap restructure, not a numeric one: for random
    // sparse instances, random staleness (both endpoints and the middle),
    // and random selection σ, every (backend, threads) cell must produce
    // the same bits as the single-threaded shared run — and the sharded
    // plane's deterministic counters must be thread-invariant, with every
    // dag allreduce issued eagerly (only the wall-clock-derived
    // overlap_hidden_s axis may differ between runs)
    for_all(10, |rng| {
        let m = 12 + rng.next_usize(20);
        let n = 10 + rng.next_usize(20);
        let mut triplets = Vec::new();
        for j in 0..n {
            for _ in 0..(1 + rng.next_usize(3)) {
                triplets.push((rng.next_usize(m), j, rng.next_normal()));
            }
        }
        let a = Matrix::Sparse(CscMatrix::from_triplets(m, n, &triplets));
        let b: Vec<f64> = (0..m).map(|_| rng.next_normal()).collect();
        let p = LassoProblem::new(a, b, 0.1, None);
        let x0 = vec![0.0; p.n()];
        let staleness = [0usize, 1, 2, usize::MAX][rng.next_usize(4)];
        let sigma = rng.uniform(0.0, 0.9);
        let schedule = Schedule::Dag { staleness };

        let base = engine::solve(&p, &x0, &random_dag_spec(schedule, 1, Backend::Shared, sigma));
        let mut counters: Option<(usize, u64, usize)> = None;
        for threads in [1usize, 2, 4] {
            for backend in [Backend::Shared, Backend::Sharded] {
                let r = engine::solve(&p, &x0, &random_dag_spec(schedule, threads, backend, sigma));
                assert_eq!(
                    r.x, base.x,
                    "dag:{staleness} σ={sigma:.3} diverged at threads={threads} {backend:?}"
                );
                assert_eq!(r.final_obj.to_bits(), base.final_obj.to_bits());
                if backend == Backend::Sharded {
                    assert_eq!(
                        r.comm.eager_rounds, r.comm.allreduce_rounds,
                        "every dag allreduce must be issued eagerly"
                    );
                    assert!(r.comm.overlap_hidden_s >= 0.0);
                    let c = (
                        r.comm.allreduce_rounds,
                        r.comm.allreduce_words.to_bits(),
                        r.comm.sync_rounds,
                    );
                    match counters {
                        None => counters = Some(c),
                        Some(prev) => assert_eq!(
                            c, prev,
                            "deterministic comm counters drifted across thread counts"
                        ),
                    }
                } else {
                    assert!(r.comm.is_empty(), "the shared plane must meter nothing");
                }
            }
        }
    });
}

#[test]
fn prop_csc_adjoint_identity() {
    // ⟨Ax, y⟩ = ⟨x, Aᵀy⟩ for random sparse instances (matvec and
    // matvec_t are transposes of each other, up to f64 reassociation)
    for_all(100, |rng| {
        let m = 1 + rng.next_usize(40);
        let n = 1 + rng.next_usize(40);
        let mut triplets = Vec::new();
        for _ in 0..rng.next_usize(3 * (m + n) + 1) {
            triplets.push((rng.next_usize(m), rng.next_usize(n), rng.next_normal()));
        }
        let a = CscMatrix::from_triplets(m, n, &triplets);
        let x: Vec<f64> = (0..n).map(|_| rng.next_normal()).collect();
        let y: Vec<f64> = (0..m).map(|_| rng.next_normal()).collect();
        let mut ax = vec![0.0; m];
        a.matvec(&x, &mut ax);
        let mut aty = vec![0.0; n];
        a.matvec_t(&y, &mut aty);
        let lhs = vector::dot(&ax, &y);
        let rhs = vector::dot(&x, &aty);
        let scale: f64 = triplets.iter().map(|t| t.2.abs()).sum::<f64>()
            * x.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0)
            * y.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
        assert!(
            (lhs - rhs).abs() <= 1e-12 * scale.max(1.0),
            "adjoint identity violated: {lhs} vs {rhs} (scale {scale})"
        );
    });
}

#[test]
fn prop_nesterov_generator_kkt() {
    // the generator's certificate holds for every (m, n, sparsity, c)
    for_all(30, |rng| {
        let m = 10 + rng.next_usize(40);
        let n = 10 + rng.next_usize(60);
        let sparsity = rng.uniform(0.01, 0.5);
        let c = rng.uniform(0.1, 10.0);
        let inst = nesterov_lasso(m, n, sparsity, c, rng.next_u64());
        let mut r = vec![0.0; m];
        inst.a.matvec(&inst.x_star, &mut r);
        for (ri, bi) in r.iter_mut().zip(&inst.b) {
            *ri -= bi;
        }
        for i in 0..n {
            let g = 2.0 * inst.a.col_dot(i, &r);
            if inst.x_star[i] != 0.0 {
                assert!((g + c * inst.x_star[i].signum()).abs() < 1e-8 * c.max(1.0));
            } else {
                assert!(g.abs() <= c * (1.0 + 1e-9));
            }
        }
    });
}
