//! Integration: the baseline solvers all reach the same optimum as FLEXA
//! on the Nesterov instances (the precondition for every comparison figure),
//! and the qualitative orderings the paper reports hold on scaled replicas.

use flexa::coordinator::{flexa as run_flexa, CommonOptions, FlexaOptions, SelectionSpec, TermMetric};
use flexa::datagen::nesterov_lasso;
use flexa::metrics::{XAxis, YMetric};
use flexa::problems::{LassoProblem, Problem};
use flexa::solvers::{admm, cdm, fista, greedy_1bcd, grock, sparsa, AdmmOptions, SparsaOptions};

fn common(name: &str, tol: f64) -> CommonOptions {
    CommonOptions {
        max_iters: 50_000,
        max_wall_s: 60.0,
        tol,
        term: TermMetric::RelErr,
        name: name.into(),
        ..Default::default()
    }
}

#[test]
fn all_solvers_reach_the_known_optimum() {
    // near-orthogonal ensemble (m >> n): the regime where even GRock's
    // parallel full steps are covered by its theory
    let p = LassoProblem::from_instance(nesterov_lasso(300, 80, 0.1, 1.0, 33));
    let x0 = vec![0.0; p.n()];
    let tol = 1e-4;
    let runs = vec![
        ("fista", fista(&p, &x0, &common("fista", tol))),
        ("sparsa", sparsa(&p, &x0, &common("sparsa", tol), &SparsaOptions::default())),
        ("grock-8", grock(&p, &x0, &common("grock", tol), 8)),
        ("1bcd", greedy_1bcd(&p, &x0, &common("1bcd", tol))),
        ("admm", admm(&p, &x0, &common("admm", tol), &AdmmOptions::default())),
        ("cdm", cdm(&p, &x0, &common("cdm", tol), true)),
    ];
    for (name, r) in runs {
        assert!(r.converged(), "{name}: {:?} re={}", r.stop, r.final_rel_err);
        assert!(r.final_rel_err <= tol, "{name}: re={}", r.final_rel_err);
    }
}

#[test]
fn grock_diverges_on_strongly_correlated_columns() {
    // the paper's caveat, reproduced as behavior: m < n Gaussian ensemble
    // (strong column correlations) breaks GRock's parallel full steps,
    // while greedy-1BCD (its safe special case) still converges
    let p = LassoProblem::from_instance(nesterov_lasso(60, 80, 0.1, 1.0, 33));
    let x0 = vec![0.0; p.n()];
    let mut c = common("grock", 1e-4);
    c.max_iters = 5000;
    let rg = grock(&p, &x0, &c, 8);
    assert!(!rg.converged(), "GRock-8 should struggle here, got {:?}", rg.stop);
    let r1 = greedy_1bcd(&p, &x0, &common("1bcd", 1e-4));
    assert!(r1.converged(), "1bcd must still converge: {:?}", r1.stop);
}

#[test]
fn flexa_beats_fista_in_iterations_on_sparse_lasso() {
    // the headline qualitative result of Fig. 1: FLEXA σ=0.5 converges in
    // far fewer iterations than FISTA on sparse instances
    let p = LassoProblem::from_instance(nesterov_lasso(90, 100, 0.01, 1.0, 44));
    let x0 = vec![0.0; p.n()];
    let tol = 1e-6;
    let rf = run_flexa(
        &p,
        &x0,
        &FlexaOptions {
            common: common("flexa", tol),
            selection: SelectionSpec::sigma(0.5),
            inexact: None,
        },
    );
    let rb = fista(&p, &x0, &common("fista", tol));
    assert!(rf.converged() && rb.converged());
    let if_ = rf.trace.x_to_tol(XAxis::Iterations, YMetric::RelErr, tol).unwrap();
    let ib = rb.trace.x_to_tol(XAxis::Iterations, YMetric::RelErr, tol).unwrap();
    assert!(
        if_ < ib,
        "FLEXA iters {if_} not better than FISTA {ib} on a sparse instance"
    );
}

#[test]
fn selective_flexa_beats_full_jacobi_on_dense_solutions() {
    // Fig. 1(d/e): as solutions get denser, σ=0.5 keeps an edge over σ=0
    // in *flops to tolerance* (it skips near-converged blocks' updates)
    let p = LassoProblem::from_instance(nesterov_lasso(90, 100, 0.4, 1.0, 55));
    let x0 = vec![0.0; p.n()];
    let tol = 1e-5;
    let run = |sigma: f64| {
        run_flexa(
            &p,
            &x0,
            &FlexaOptions {
                common: common(&format!("s{sigma}"), tol),
                selection: SelectionSpec::sigma(sigma),
                inexact: None,
            },
        )
    };
    let r_sel = run(0.5);
    let r_full = run(0.0);
    assert!(r_sel.converged() && r_full.converged());
    let f_sel = r_sel.trace.flops_to_tol(YMetric::RelErr, tol).unwrap();
    let f_full = r_full.trace.flops_to_tol(YMetric::RelErr, tol).unwrap();
    assert!(
        f_sel <= f_full * 1.2,
        "selective flops {f_sel:.3e} much worse than full {f_full:.3e}"
    );
}

#[test]
fn grock_struggles_when_columns_correlate() {
    // the paper's caveat: GRock's convergence is in jeopardy off the
    // near-orthogonal regime. We assert the *relative* degradation: its
    // advantage over FLEXA evaporates on a dense-solution instance.
    let p = LassoProblem::from_instance(nesterov_lasso(120, 200, 0.3, 1.0, 66));
    let x0 = vec![0.0; p.n()];
    let tol = 1e-3;
    let mut c = common("grock", tol);
    c.max_iters = 3000;
    let rg = grock(&p, &x0, &c, 40);
    let rf = run_flexa(
        &p,
        &x0,
        &FlexaOptions {
            common: common("flexa", tol),
            selection: SelectionSpec::sigma(0.5),
            inexact: None,
        },
    );
    assert!(rf.converged());
    // either GRock fails to converge in the budget, or needs more iterations
    if rg.converged() {
        let ig = rg.trace.x_to_tol(XAxis::Iterations, YMetric::RelErr, tol).unwrap();
        let if_ = rf.trace.x_to_tol(XAxis::Iterations, YMetric::RelErr, tol).unwrap();
        assert!(ig >= if_ * 0.5, "GRock unexpectedly dominant on correlated columns");
    }
}

#[test]
fn simulated_time_scales_with_cores_for_parallel_solvers() {
    // large enough that compute dominates the m-word allreduce — on tiny
    // instances the model correctly shows communication eating the speedup
    // (the paper's own observation for logistic regression)
    let p = LassoProblem::from_instance(nesterov_lasso(400, 600, 0.05, 1.0, 77));
    let x0 = vec![0.0; p.n()];
    let run = |cores: usize| {
        let mut c = common("flexa", 1e-5);
        c.cores = cores;
        run_flexa(
            &p,
            &x0,
            &FlexaOptions {
                common: c,
                selection: SelectionSpec::sigma(0.5),
                inexact: None,
            },
        )
    };
    let r1 = run(1);
    let r8 = run(8);
    assert!(r1.converged() && r8.converged());
    assert!(
        r8.sim_s < r1.sim_s,
        "8 simulated cores not faster: {} vs {}",
        r8.sim_s,
        r1.sim_s
    );
    // the paper's Remark 5: going 8→20 cores roughly halves the time on
    // compute-bound instances; here we just require meaningful speedup
    assert!(r8.sim_s < r1.sim_s * 0.5, "speedup too weak: {} vs {}", r8.sim_s, r1.sim_s);
}
