//! Engine-level oracles for the two analytic endpoints of the dag
//! schedule's staleness spectrum (`--schedule dag:N`, the barrier-free
//! dependency-graph epoch engine of `engine::depgraph` +
//! `parallel::epoch`):
//!
//! * **`dag:0`** forbids any write to land between an adjacent read and
//!   its write — on a sparse problem the event graph orders every
//!   adjacent pair write-before-read by color, which is exactly
//!   **chromatic Gauss-Seidel**: colors ascending, each block's best
//!   response reading every lower color's already-applied steps.
//! * **`dag:∞`** removes all cross-block read/write ordering except the
//!   determinism chain — on a dense problem every read drains before the
//!   first write and the writes apply in ascending block order, which is
//!   exactly the **Jacobi** iteration (all responses against the
//!   iteration-start state) with a fixed merge order.
//!
//! Both oracles are hand-rolled sequential loops over the public
//! [`Problem`] surface — no engine code — and the engine must match them
//! **bitwise** at every thread count. This pins the *semantics* of the
//! scheduler (what iteration it computes), complementing the replay-
//! determinism tests (that it computes the same thing twice).

use flexa::coordinator::{
    Backend, CommonOptions, Schedule, SelectionSpec, StepRule, TermMetric,
};
use flexa::engine::{self, DepGraph, DirectionRule, MergeRule, SolverSpec};
use flexa::linalg::{CscMatrix, Matrix};
use flexa::problems::{LassoProblem, Problem};

const ITERS: usize = 12;
const GAMMA: f64 = 0.5;
const TAU: f64 = 0.3;

/// A FLEXA spec pinned so the engine's dag arm is analytically
/// predictable: fixed γ (no adaptive schedule), fixed τ (no controller,
/// no accept/reject), σ = 0 (every block selected every iteration).
fn pinned_spec(schedule: Schedule, threads: usize, backend: Backend) -> SolverSpec {
    SolverSpec {
        common: CommonOptions {
            max_iters: ITERS,
            tol: 0.0,
            term: TermMetric::Merit,
            cores: 4,
            threads,
            backend,
            schedule,
            stepsize: StepRule::Constant { gamma: GAMMA },
            name: format!("dag-oracle@{}", schedule.name()),
            ..Default::default()
        },
        direction: DirectionRule::BestResponse { tau0: Some(TAU) },
        merge: MergeRule::Jacobi { full_step: false },
        selection: Some(SelectionSpec::sigma(0.0)),
        inexact: None,
    }
}

/// One memory step (S.4) of block `i` against the *current* `x`/`aux`,
/// replicating the engine's W-event arithmetic exactly: per-coordinate
/// `d = γ(ẑ_j − x_j)`, and the block moves (x update + aux delta column)
/// only if some coordinate moved.
fn write_block(
    p: &dyn Problem,
    i: usize,
    z: &[f64],
    dx: &mut [f64],
    x: &mut [f64],
    aux: &mut [f64],
) -> bool {
    let r = p.blocks().range(i);
    let mut any = false;
    for j in r.clone() {
        let d = GAMMA * (z[j] - x[j]);
        dx[j] = d;
        if d != 0.0 {
            any = true;
        }
    }
    if any {
        for j in r.clone() {
            x[j] += dx[j];
        }
        p.apply_block_delta(i, &dx[r], aux);
    }
    any
}

/// Chromatic Gauss-Seidel: colors ascending; every block of a color
/// takes its best response against all lower colors' applied steps.
/// Same-color blocks have disjoint supports, so their order within the
/// color is immaterial (ascending here).
fn chromatic_gs_oracle(p: &dyn Problem, x0: &[f64], tau: f64) -> Vec<f64> {
    let dep = DepGraph::build(p);
    let nb = p.blocks().n_blocks();
    let mut x = x0.to_vec();
    let mut aux = vec![0.0; p.aux_len()];
    p.init_aux(&x, &mut aux);
    let mut z = vec![0.0; p.n()];
    let mut dx = vec![0.0; p.n()];
    for _ in 0..ITERS {
        for c in 0..dep.n_colors {
            for i in (0..nb).filter(|&i| dep.color[i] == c) {
                let r = p.blocks().range(i);
                p.best_response(i, &x, &aux, tau, &mut z[r]);
                write_block(p, i, &z, &mut dx, &mut x, &mut aux);
            }
        }
    }
    x
}

/// Jacobi with a pinned merge order: all best responses against the
/// iteration-start state, then the memory steps applied in ascending
/// block order (the engine's write chain — the fixed summation order
/// that makes the dense dag deterministic).
fn jacobi_read_oracle(p: &dyn Problem, x0: &[f64], tau: f64) -> Vec<f64> {
    let nb = p.blocks().n_blocks();
    let mut x = x0.to_vec();
    let mut aux = vec![0.0; p.aux_len()];
    p.init_aux(&x, &mut aux);
    let mut z = vec![0.0; p.n()];
    let mut dx = vec![0.0; p.n()];
    for _ in 0..ITERS {
        for i in 0..nb {
            let r = p.blocks().range(i);
            p.best_response(i, &x, &aux, tau, &mut z[r]);
        }
        for i in 0..nb {
            write_block(p, i, &z, &mut dx, &mut x, &mut aux);
        }
    }
    x
}

/// Banded sparse LASSO whose columns overlap without being complete:
/// the dependency graph is genuinely sparse (several blocks per color),
/// so chromatic GS and Jacobi are distinct iterations.
fn banded_lasso() -> LassoProblem {
    let (m, n) = (30usize, 24usize);
    let mut t = Vec::new();
    for j in 0..n {
        for d in 0..3usize {
            t.push(((j * 2 + d * 5) % m, j, 1.0 + (j + d) as f64 * 0.1));
        }
    }
    let a = Matrix::Sparse(CscMatrix::from_triplets(m, n, &t));
    let b: Vec<f64> = (0..m).map(|r| (r % 7) as f64 * 0.3 - 1.0).collect();
    LassoProblem::new(a, b, 0.05, None)
}

#[test]
fn dag_zero_staleness_is_chromatic_gauss_seidel_bitwise() {
    let p = banded_lasso();
    let x0 = vec![0.0; p.n()];
    let tau = TAU.max(p.tau_min()); // the engine's pinned-τ floor

    // the workload must exercise real concurrency: a sparse coloring
    // with more than one block per color and more than one color
    let dep = DepGraph::build(&p);
    assert!(!dep.dense, "banded CSC instance must color sparsely");
    assert!(dep.n_colors > 1 && dep.n_colors < dep.n_blocks());

    let want = chromatic_gs_oracle(&p, &x0, tau);
    for threads in [1usize, 2, 4] {
        let spec = pinned_spec(Schedule::Dag { staleness: 0 }, threads, Backend::Shared);
        let r = engine::solve(&p, &x0, &spec);
        assert_eq!(r.iters, ITERS);
        assert_eq!(
            r.x, want,
            "dag:0 must equal the chromatic Gauss-Seidel oracle bitwise \
             (threads={threads})"
        );
    }
    let sharded = engine::solve(
        &p,
        &x0,
        &pinned_spec(Schedule::Dag { staleness: 0 }, 4, Backend::Sharded),
    );
    assert_eq!(sharded.x, want, "sharded dag:0 must match the oracle bitwise");

    // sanity: at these endpoints the two oracles are *different*
    // iterations — otherwise the test would prove nothing
    let jacobi = jacobi_read_oracle(&p, &x0, tau);
    assert_ne!(want, jacobi, "GS and Jacobi coincide — workload too decoupled");
}

#[test]
fn dag_infinite_staleness_is_jacobi_reads_bitwise() {
    // dense data: every pair of blocks couples, the graph degenerates to
    // the complete graph, and dag:∞ keeps only the determinism chain
    let p = LassoProblem::from_instance(flexa::datagen::nesterov_lasso(
        40, 24, 0.1, 1.0, 17,
    ));
    let x0 = vec![0.0; p.n()];
    let tau = TAU.max(p.tau_min());
    assert!(DepGraph::build(&p).dense, "dense instance must fall back to dense mode");

    let want = jacobi_read_oracle(&p, &x0, tau);
    for threads in [1usize, 2, 4] {
        let spec = pinned_spec(
            Schedule::Dag { staleness: usize::MAX },
            threads,
            Backend::Shared,
        );
        let r = engine::solve(&p, &x0, &spec);
        assert_eq!(r.iters, ITERS);
        assert_eq!(
            r.x, want,
            "dag:inf must equal the Jacobi-read oracle bitwise (threads={threads})"
        );
    }

    // the engine's own barrier Jacobi computes the same mathematical
    // iteration; its merge applies deltas in the same ascending block
    // order, so the barrier run corroborates the oracle bitwise
    let barrier = engine::solve(&p, &x0, &pinned_spec(Schedule::Barrier, 1, Backend::Shared));
    assert_eq!(
        barrier.x, want,
        "barrier Jacobi disagrees with the Jacobi-read oracle"
    );
}

/// The communication plane's dag accounting against a hand-rolled
/// oracle: on the sharded dag, every iteration issues exactly one eager
/// aux wavefront per color whose blocks *moved* — so the oracle re-runs
/// chromatic Gauss-Seidel counting distinct moved colors, and the
/// engine's `CommStats` must match it exactly (and identically at every
/// thread count, since the counters are part of the determinism
/// contract; only the wall-clock-derived `overlap_hidden_s` may vary).
#[test]
fn sharded_dag_comm_counters_match_the_moved_color_oracle() {
    let p = banded_lasso();
    let x0 = vec![0.0; p.n()];
    let tau = TAU.max(p.tau_min());
    let dep = DepGraph::build(&p);
    let nb = p.blocks().n_blocks();

    // oracle rounds: one wavefront per (iteration, color with ≥1 moved
    // block) — the same chromatic-GS loop as above, counting moves
    let mut x = x0.clone();
    let mut aux = vec![0.0; p.aux_len()];
    p.init_aux(&x, &mut aux);
    let mut z = vec![0.0; p.n()];
    let mut dx = vec![0.0; p.n()];
    let mut rounds = 0usize;
    for _ in 0..ITERS {
        let mut stamped = vec![false; dep.n_colors];
        for c in 0..dep.n_colors {
            for i in (0..nb).filter(|&i| dep.color[i] == c) {
                let r = p.blocks().range(i);
                p.best_response(i, &x, &aux, tau, &mut z[r]);
                if write_block(&p, i, &z, &mut dx, &mut x, &mut aux) && !stamped[c] {
                    stamped[c] = true;
                    rounds += 1;
                }
            }
        }
    }
    assert!(rounds > 0, "oracle must count at least one wavefront");
    assert!(
        rounds <= ITERS * dep.n_colors,
        "at most one wavefront per color per iteration"
    );

    let want = chromatic_gs_oracle(&p, &x0, tau);
    for threads in [1usize, 2, 4] {
        let spec = pinned_spec(Schedule::Dag { staleness: 0 }, threads, Backend::Sharded);
        let r = engine::solve(&p, &x0, &spec);
        assert_eq!(r.iters, ITERS);
        assert_eq!(
            r.x, want,
            "sharded dag:0 must equal the chromatic GS oracle (threads={threads})"
        );
        assert_eq!(
            r.comm.allreduce_rounds, rounds,
            "one allreduce per moved color per iteration (threads={threads})"
        );
        assert_eq!(
            r.comm.eager_rounds, rounds,
            "every dag wavefront is issued eagerly (threads={threads})"
        );
        assert_eq!(
            r.comm.allreduce_words,
            rounds as f64 * p.aux_len() as f64,
            "each wavefront moves the full m-word aux vector (threads={threads})"
        );
        assert_eq!(
            r.comm.sync_rounds, ITERS,
            "one M^k/S^k scalar sync per iteration (threads={threads})"
        );
        assert!(r.comm.overlap_hidden_s >= 0.0);
        assert_eq!(r.comm.broadcast_rounds, 0, "no sweeps on this path");
    }
}

/// Satellite check on the simulator: its barrier-idle prediction
/// (`CostModel::barrier_idle_s` over the report's predicted reduction
/// rounds) must track the *measured* `SchedStats::barrier_idle_s` of a
/// real multi-threaded barrier run. The documented agreement band is
/// four orders of magnitude either way — deliberately wide, because the
/// model charges a fixed 1 µs per round while the measured figure is
/// scheduler-jitter-dominated at this fixture's scale; the band still
/// catches the regressions that matter (a prediction of zero, a measured
/// axis that stops being wired up, or a units mixup on either side).
#[test]
fn simulator_barrier_idle_prediction_tracks_measured_idle() {
    let p = banded_lasso();
    let x0 = vec![0.0; p.n()];
    let mut spec = pinned_spec(Schedule::Barrier, 2, Backend::Shared);
    // more fixed-work iterations than the oracles use, so the measured
    // idle accumulates well clear of timer granularity
    spec.common.max_iters = 5 * ITERS;
    let r = engine::solve(&p, &x0, &spec);

    let model = flexa::simulator::CostModel::default();
    let predicted = model.barrier_idle_s(r.predicted_rounds, 2);
    let measured = r.sched.barrier_idle_s;
    assert!(predicted > 0.0, "barrier runs must predict nonzero rounds");
    assert!(measured > 0.0, "a threads=2 barrier run must measure some idle");
    let log_ratio = (measured / predicted).log10().abs();
    assert!(
        log_ratio <= 4.0,
        "measured barrier idle {measured:.3e}s vs predicted {predicted:.3e}s \
         disagree by 10^{log_ratio:.2} (> 10^4): simulator and scheduler \
         accounting have drifted apart"
    );
}
